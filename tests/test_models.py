"""Per-arch smoke tests (reduced same-family configs, assignment req.)
plus the strongest whole-model invariant we have: token-by-token decode
against the cache must reproduce the full-sequence forward logits."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.archs import ASSIGNED, reduced
from repro.configs.base import layer_plan
from repro.models.transformer import TransformerLM


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.cross_kv_len, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.enc_dec:
        b["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)) * 0.1, jnp.float32)
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_train_step(arch):
    """One forward/train step on CPU: finite loss, finite grads."""
    cfg = reduced(get_config(arch))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in leaves)
    # Fresh model ≈ uniform: CE near log(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_output_shapes(arch):
    cfg = reduced(get_config(arch))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    batch = _batch(cfg, B, S)
    x = lm.embed(params, batch["tokens"])
    kv = batch.get("image_embeds", batch.get("frame_embeds"))
    if cfg.enc_dec:
        kv = lm.encode(params, batch["frame_embeds"])
    h, _, _ = lm.trunk(params, x, mode="train",
                       positions=jnp.arange(S, dtype=jnp.int32), kv_src=kv)
    assert h.shape == (B, S, cfg.d_model)
    lg = lm.logits(params, h)
    assert lg.shape == (B, S, cfg.vocab)


@pytest.mark.parametrize("arch", ["granite-3-8b", "mixtral-8x22b",
                                  "deepseek-v2-lite-16b", "mamba2-370m",
                                  "jamba-v0.1-52b", "stablelm-3b"])
def test_decode_matches_full_forward(arch):
    """prefill(t[:k]) + decode steps ≡ full forward — the KV-cache /
    SSM-state correctness invariant that serving relies on."""
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        # Capacity is token-count-dependent; make it ample so routing
        # drops nothing in either pass (otherwise prefill-vs-train drop
        # patterns legitimately differ — that's load-dependent lossiness,
        # not a cache bug).
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(1))
    B, S, PRE = 2, 12, 6
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    # full forward logits
    x = lm.embed(params, toks)
    h, _, _ = lm.trunk(params, x, mode="train",
                       positions=jnp.arange(S, dtype=jnp.int32))
    full = np.asarray(lm.logits(params, h), np.float32)

    # prefill on the prefix, then decode the rest token by token
    lg, cache = lm.prefill(params, toks[:, :PRE])
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               full[:, PRE - 1], rtol=2e-3, atol=2e-3)
    # grow the cache to S rows (prefill cache is PRE rows)
    pool = lm.init_cache(B, S, dtype=jnp.float32)

    def graft(p, c):
        pads = [(0, a - b) for a, b in zip(p.shape, c.shape)]
        return jnp.pad(c.astype(p.dtype), pads)

    cache = jax.tree.map(graft, pool, cache)
    for t in range(PRE, S):
        lg, cache = lm.decode_step(params, cache, toks[:, t: t + 1],
                                   jnp.full((B,), t, jnp.int32))
        if t + 1 < S:
            np.testing.assert_allclose(np.asarray(lg, np.float32),
                                       full[:, t], rtol=2e-3, atol=2e-3)


def test_pattern_plan_periods():
    """layer_plan must reproduce each arch's published layer pattern."""
    jamba = get_config("jamba-v0.1-52b")
    pro, pat, reps = layer_plan(jamba)
    assert len(pro) == 0 and len(pat) * reps == 32
    assert sum(d.mixer == "attn" for d in pat) * reps == 4   # 1:7 ratio
    assert sum(d.mlp == "moe" for d in pat) * reps == 16     # every 2nd

    ds = get_config("deepseek-v2-lite-16b")
    pro, pat, reps = layer_plan(ds)
    assert len(pro) == 1 and pro[0].mlp == "dense"           # first dense
    assert all(d.mlp == "moe" for d in pat)
    assert all(d.mixer == "mla" for d in pat)

    vlm = get_config("llama-3.2-vision-90b")
    pro, pat, reps = layer_plan(vlm)
    assert sum(d.cross for d in pat) * reps == 20            # every 5th

    mam = get_config("mamba2-370m")
    _, pat, reps = layer_plan(mam)
    assert all(d.mixer == "mamba" and d.mlp == "none" for d in pat)


def test_param_count_sanity():
    """Closed-form parameter counts within tolerance of the headline
    sizes (these are the 6·N·D inputs — they must be right)."""
    expect = {
        "llama3-405b": (405e9, 0.10),
        "mixtral-8x22b": (141e9, 0.10),
        "command-r-35b": (35e9, 0.20),
        "granite-3-8b": (8e9, 0.25),
        "mamba2-370m": (370e6, 0.25),
    }
    for arch, (n, tol) in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert abs(got - n) / n < tol, f"{arch}: {got:.3g} vs {n:.3g}"
    # MoE active < total
    mix = get_config("mixtral-8x22b")
    assert mix.active_param_count() < 0.45 * mix.param_count()


def test_swa_rolling_cache_is_window_sized():
    cfg = reduced(get_config("mixtral-8x22b"))
    lm = TransformerLM(cfg)
    cache = lm.init_cache(2, 4096)
    # attention caches bounded by the window, not max_len
    def check(path, leaf):
        return leaf
    k = cache["pattern"][0]["attn"]["k"]
    assert k.shape[-2] <= cfg.window


def test_tied_embeddings_option():
    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")),
                              tie_embeddings=True)
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    assert "lm_head" not in params
    loss, _ = jax.jit(lm.loss)(params, _batch(cfg))
    assert np.isfinite(float(loss))
