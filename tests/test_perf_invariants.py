"""Regression tests pinning the §Perf findings (EXPERIMENTS.md §4 /
DESIGN.md §8) — each of these encodes a multi-TB/step failure mode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.archs import ASSIGNED, reduced
from repro.models.layers import axis_rules, logical_spec
from repro.models.transformer import TransformerLM


def test_vocab_padding_alignment():
    """I9: every arch's padded vocab tiles a 16-way mesh axis."""
    for arch in ASSIGNED:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab
        assert cfg.vocab_padded - cfg.vocab < 256


def test_pad_logits_masked_everywhere():
    """Pad-vocab logits must be -inf: never sampled, excluded by CE."""
    cfg = dataclasses.replace(reduced(get_config("seamless-m4t-large-v2")),
                              vocab=500)     # 500 → padded 512
    assert cfg.vocab_padded == 512
    lm = TransformerLM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 4), jnp.int32)
    fe = jnp.zeros((1, 8, cfg.d_model), jnp.float32)
    x = lm.embed(params, toks)
    h, _, _ = lm.trunk(params, x, mode="train",
                       positions=jnp.arange(4, dtype=jnp.int32),
                       kv_src=lm.encode(params, fe))
    lg = np.asarray(lm.logits(params, h), np.float32)
    assert lg.shape[-1] == 512
    assert np.all(lg[..., 500:] < -1e20)           # masked
    assert np.all(np.argmax(lg, -1) < 500)          # never sampled
    loss, _ = lm.loss(params, {"tokens": toks, "labels": toks,
                               "frame_embeds": fe})
    assert np.isfinite(float(loss))
    # CE ≈ log(REAL vocab): pad logits contribute nothing to the lse
    assert abs(float(loss) - np.log(500)) < 1.0


def test_sp_dedupe_mlp_keeps_ff():
    """I4: inside the MLP, ff must keep the model axis even under SP."""
    rules = {"batch": "data", "seq": "model", "ff": "model", "heads": "model",
             "__sizes__": {"data": 16, "model": 16}}
    with axis_rules(rules):
        # residual stream (between blocks): seq gets the model axis
        assert logical_spec(("batch", "seq", None), (256, 4096, 1024)) == \
            P("data", "model", None)
        # MLP hidden (inside): ff must get it — the I4 bug was naming seq
        assert logical_spec(("batch", None, "ff"), (256, 4096, 4096)) == \
            P("data", None, "model")
        # attention: heads win over seq (dedupe order)
        assert logical_spec(("batch", "heads", "seq", None),
                            (256, 32, 4096, 128)) == \
            P("data", "model", None, None)


def test_divisibility_gate_in_logical_spec():
    """Non-divisible dims silently replicating caused I9; the gate must
    drop the axis instead of producing an invalid/uneven constraint."""
    rules = {"vocab": "model", "__sizes__": {"model": 16}}
    with axis_rules(rules):
        assert logical_spec(("vocab",), (256206,)) == P(None)     # ∤ 16
        assert logical_spec(("vocab",), (256256,)) == P("model")  # ✓


def test_head_major_weights_shapes():
    """I1: attention projections are head-major 3-D for whole-head TP."""
    from repro.models.attention import AttnDims, gqa_init, mla_init, MLADims
    p = gqa_init(jax.random.PRNGKey(0),
                 AttnDims(d_model=64, n_q=8, n_kv=2, head_dim=8))
    assert p["wq"].shape == (64, 8, 8)
    assert p["wk"].shape == (64, 2, 8)
    assert p["wo"].shape == (8, 8, 64)
    m = mla_init(jax.random.PRNGKey(0),
                 MLADims(d_model=64, n_heads=4, kv_lora=16, nope_dim=8,
                         rope_dim=4, v_dim=8))
    assert m["wq"].shape == (64, 4, 12)
    assert m["w_uk"].shape == (16, 4, 8)
    assert m["wo"].shape == (4, 8, 64)


def test_grad_specs_plumbed_through_accum():
    """I2/I3 support: microbatch_grads applies the constraint pytree
    without altering values (single-device: constraint is a no-op)."""
    from repro.optim import microbatch_grads
    w = jnp.ones((8, 4))
    batch = {"x": jnp.ones((8, 8)), "y": jnp.zeros((8, 4))}

    def loss_fn(p, b):
        l = jnp.mean((b["x"] @ p - b["y"]) ** 2)
        return l, {}

    mesh = jax.make_mesh((1,), ("data",))
    with mesh:
        l1, g1, _ = microbatch_grads(loss_fn, w, batch, 2)
        l2, g2, _ = microbatch_grads(loss_fn, w, batch, 2, grad_specs=P())
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-6)


def test_dp_shards_reads_rules():
    from repro.models.layers import dp_shards
    assert dp_shards() == 1
    with axis_rules({"batch": ("pod", "data"),
                     "__sizes__": {"pod": 2, "data": 16, "model": 16}}):
        assert dp_shards() == 32
    with axis_rules({"batch": "data", "__sizes__": {"data": 16}}):
        assert dp_shards() == 16
