"""Trainer integration: loss goes down, checkpoint/restart determinism,
failure injection → recovery, metric logging."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.fault import FaultInjector, HeartbeatMonitor, RestartPolicy
from repro.train import MetricLogger, TrainConfig, Trainer


def make_problem(seed=0):
    """Tiny regression LM-alike: learn y = x @ w_true."""
    rng = np.random.default_rng(seed)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)

    def init_params(key):
        return {"w": jnp.zeros((8, 4), jnp.float32)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def batches(seed=0):
        r = np.random.default_rng(seed)
        while True:
            x = jnp.asarray(r.standard_normal((16, 8)), jnp.float32)
            yield {"x": x, "y": x @ w_true}

    return init_params, loss_fn, batches


def test_loss_decreases():
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init, TrainConfig(lr=0.05, warmup_steps=5, weight_decay=0.0,
                                            total_steps=60, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, batches(), steps=60, logger=logger)
    assert logger.history[-1]["loss"] < 0.05 * logger.history[0]["loss"]


def test_caller_owned_generator_survives_staged_fits():
    """fit() must not close a caller-owned generator: staged training
    resumes consuming the SAME stream across fit() calls (guards both
    the close() ownership check and _chain_first's non-delegating
    abandonment)."""
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init, TrainConfig(lr=0.05, warmup_steps=5,
                                            weight_decay=0.0,
                                            total_steps=40, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    stream = batches()
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, stream, steps=15, logger=logger)
    state, logger = tr.fit(state, stream, steps=40, logger=logger)
    assert int(np.asarray(state.step)) == 40


def test_resume_is_deterministic(tmp_path):
    """run 40 steps straight  ≡  run 20, 'crash', restore, run 20."""
    init, loss_fn, batches = make_problem()

    def fit(ckpt_dir, stop_at, resume=False):
        tr = Trainer(loss_fn, init,
                     TrainConfig(lr=0.05, warmup_steps=5, total_steps=40,
                                 ckpt_dir=ckpt_dir, ckpt_every=20,
                                 log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))
        start = 0
        if resume:
            state, start = tr.maybe_restore(state)
        # a restartable batch stream positioned at the right step
        stream = batches()
        for _ in range(start):
            next(stream)
        state, _ = tr.fit(state, stream, steps=stop_at)
        return state

    s_straight = fit(str(tmp_path / "a"), 40)
    fit(str(tmp_path / "b"), 20)
    s_resumed = fit(str(tmp_path / "b"), 40, resume=True)
    np.testing.assert_allclose(np.asarray(s_straight.params["w"]),
                               np.asarray(s_resumed.params["w"]),
                               rtol=1e-6, atol=1e-7)


def test_failure_injection_recovers(tmp_path):
    """A simulated node failure mid-run must restore the last commit and
    still converge."""
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=60,
                             weight_decay=0.0,
                             ckpt_dir=str(tmp_path), ckpt_every=10,
                             log_every=100))
    state = tr.init_state(jax.random.PRNGKey(0))
    inj = FaultInjector(fail_at_steps=[25])
    logger = MetricLogger()
    state, logger = tr.fit(state, batches(), steps=60, logger=logger,
                           fault_injector=inj)
    assert inj.failures == [25]
    assert int(np.asarray(state.step)) == 60
    # ~5 steps of progress re-done after the restore; still converging
    assert logger.history[-1]["loss"] < 0.3


def test_failure_before_first_checkpoint_reinits_params(tmp_path):
    """A crash BEFORE the first checkpoint commit must restart from a
    fresh init (the recorded init rng), not from the zeroed restore
    twin.  Uses a non-zero init so the two are distinguishable, and
    ckpt_every > steps so nothing is ever committed mid-run."""
    rng = np.random.default_rng(3)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    w_init = rng.standard_normal((8, 4)).astype(np.float32)

    def init_params(key):
        # fresh device array per call — the previous one may have been
        # donated to the jitted step and deleted
        return {"w": jnp.asarray(w_init)}

    def loss_fn(p, batch):
        l = jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)
        return l, {"mse": l}

    def batches(seed=0):
        r = np.random.default_rng(seed)
        while True:
            x = jnp.asarray(r.standard_normal((16, 8)), jnp.float32)
            yield {"x": x, "y": x @ w_true}

    def fit(ckpt_dir, fail_at, skip_first=False):
        tr = Trainer(loss_fn, init_params,
                     TrainConfig(lr=0.05, warmup_steps=5, total_steps=12,
                                 weight_decay=0.0, ckpt_dir=ckpt_dir,
                                 ckpt_every=100,     # > steps: no commit
                                 log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))
        inj = FaultInjector(fail_at_steps=fail_at) if fail_at else None
        stream = batches()
        if skip_first:
            next(stream)
        state, _ = tr.fit(state, stream, steps=12, fault_injector=inj)
        return np.asarray(state.params["w"])

    # The crash consumes batch 0 before the injector fires, so the
    # faithful fault-free reference is a run over batches 1..12.
    clean = fit(str(tmp_path / "clean"), None, skip_first=True)
    crashed = fit(str(tmp_path / "crash0"), [0])
    # re-init from the recorded rng + same batches ⇒ identical params
    np.testing.assert_array_equal(clean, crashed)
    # and it must NOT be the zeros trajectory the old code produced
    assert not np.allclose(crashed, 0.0)


def test_nonfinite_batch_skips_step_and_counts():
    """A NaN batch must not touch params/moments: the jitted guard
    drops the batch, the host counts the skip, and training continues
    to converge on the surviving batches."""
    init, loss_fn, batches = make_problem()

    def poisoned(seed=0):
        for i, b in enumerate(batches(seed)):
            if i == 3:
                bad = dict(b)
                bad["x"] = b["x"].at[0, 0].set(jnp.nan)
                yield bad
            else:
                yield b

    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=60,
                             weight_decay=0.0, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, poisoned(), steps=60, logger=logger)
    assert logger.counters["nonfinite_skips"] == 1
    assert int(np.asarray(state.step)) == 60
    assert np.isfinite(np.asarray(state.params["w"])).all()
    assert logger.history[-1]["loss"] < 0.05 * logger.history[0]["loss"]


def test_nonfinite_streak_aborts():
    """Persistent divergence is a bug, not weather: more than
    max_skip_steps consecutive non-finite steps aborts the run."""
    init, loss_fn, batches = make_problem()

    def all_nan(seed=0):
        for b in batches(seed):
            yield {"x": b["x"] * jnp.nan, "y": b["y"]}

    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=60,
                             weight_decay=0.0, log_every=100,
                             max_skip_steps=4))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    with pytest.raises(RuntimeError, match="consecutive non-finite"):
        tr.fit(state, all_nan(), steps=60, logger=logger)
    assert logger.counters["nonfinite_skips"] == 5


def test_compressed_grads_still_converge():
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=80,
                             weight_decay=0.0,
                             log_every=100, compress_grads=True))
    state = tr.init_state(jax.random.PRNGKey(0))
    state, logger = tr.fit(state, batches(), steps=80,
                           logger=MetricLogger())
    assert logger.history[-1]["loss"] < 0.1


def test_ef_residual_in_state_makes_gradient_sums_converge():
    """The advertised EF guarantee, now actually wired: with the
    residual carried in TrainState, the sum of EMITTED (quantized)
    gradients converges to the true sum; naive per-step quantization
    (the old `compress_tree(grads)` path) drifts by T·|Q(c)-c|.

    Uses a linear loss (grad ≡ c exactly, every step) and b1=0 so
    ``opt.mu`` IS the emitted gradient after each step."""
    from repro.dist.compress import fake_quant

    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.standard_normal((8, 4)) * 1e-4, jnp.float32)

    def init(key):
        return {"w": jnp.zeros((8, 4), jnp.float32)}

    def loss_fn(p, batch):
        return jnp.sum(p["w"] * batch["c"]), {}

    def batches():
        while True:
            yield {"c": c}

    steps = 50
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=1e-6, warmup_steps=1, total_steps=steps,
                             b1=0.0, weight_decay=0.0, max_grad_norm=1e9,
                             log_every=1000, compress_grads=True))
    state = tr.init_state(jax.random.PRNGKey(0))
    assert state.ef is not None            # residual lives in the state
    stream = batches()
    emitted_sum = np.zeros((8, 4))
    for t in range(steps):
        state, _ = tr.fit(state, stream, steps=t + 1)
        emitted_sum += np.asarray(state.opt.mu["w"])  # b1=0 ⇒ mu = emitted
    true = steps * np.asarray(c)
    err_ef = np.linalg.norm(emitted_sum - true)
    err_naive = np.linalg.norm(steps * np.asarray(fake_quant(c)) - true)
    assert err_naive > 0                   # quantization actually bites
    assert err_ef < err_naive * 0.5
    assert float(jnp.sum(jnp.abs(state.ef["w"]))) > 0


def test_ef_absent_without_compression():
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=5,
                             weight_decay=0.0, log_every=100))
    state = tr.init_state(jax.random.PRNGKey(0))
    assert state.ef is None
    state, _ = tr.fit(state, batches(), steps=5)
    assert state.ef is None


def test_skipped_step_leaves_ef_residual_untouched():
    """Skip-step safety: a skipped non-finite step emitted nothing, so
    the EF residual must come out bit-identical — folding the poisoned
    accumulator in would leak the dropped batch into the next step's
    emission."""
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=10,
                             weight_decay=0.0, log_every=100,
                             compress_grads=True))
    state = tr.init_state(jax.random.PRNGKey(0))
    stream = batches()
    state, _ = tr.fit(state, stream, steps=3)
    ef_before = np.asarray(state.ef["w"]).copy()
    assert np.abs(ef_before).sum() > 0
    good = next(stream)
    bad = {"x": good["x"].at[0, 0].set(jnp.nan), "y": good["y"]}
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, itertools.chain([bad], stream),
                           steps=4, logger=logger)
    assert logger.counters["nonfinite_skips"] == 1
    np.testing.assert_array_equal(np.asarray(state.ef["w"]), ef_before)
    # ...and a normal step DOES move it again
    state, _ = tr.fit(state, stream, steps=5)
    assert not np.array_equal(np.asarray(state.ef["w"]), ef_before)


def test_checkpoint_strips_and_reinits_ef(tmp_path):
    """Checkpoints must not pin the EF residual (its shape depends on
    the replica count — elastic restarts change it): saved states carry
    no ef, restore re-initializes zeros."""
    init, loss_fn, batches = make_problem()

    def trainer():
        return Trainer(loss_fn, init,
                       TrainConfig(lr=0.05, warmup_steps=5,
                                   total_steps=10, weight_decay=0.0,
                                   ckpt_dir=str(tmp_path), ckpt_every=5,
                                   log_every=100, compress_grads=True))

    tr = trainer()
    state = tr.init_state(jax.random.PRNGKey(0))
    state, _ = tr.fit(state, batches(), steps=10)
    assert float(jnp.sum(jnp.abs(state.ef["w"]))) > 0
    tr2 = trainer()
    restored, step = tr2.maybe_restore(tr2.init_state(jax.random.PRNGKey(0)))
    assert step == 10
    assert restored.ef is not None
    np.testing.assert_array_equal(np.asarray(restored.ef["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.asarray(state.params["w"]))


def test_microbatched_trainer_matches_full():
    init, loss_fn, batches = make_problem()

    def run(n_micro):
        tr = Trainer(loss_fn, init,
                     TrainConfig(lr=0.05, warmup_steps=5, total_steps=10,
                                 n_micro=n_micro, log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))
        state, _ = tr.fit(state, batches(), steps=10)
        return np.asarray(state.params["w"])

    np.testing.assert_allclose(run(1), run(4), rtol=1e-5, atol=1e-6)


def test_restart_policy_backoff():
    rp = RestartPolicy(max_restarts=3, base_delay=1.0, max_delay=10.0)
    assert rp.next_delay() == 1.0
    assert rp.next_delay() == 2.0
    rp.record_success()
    assert rp.next_delay() == 1.0
    rp.next_delay(); rp.next_delay()
    assert rp.next_delay() is None      # budget exhausted


def test_heartbeat_rejoin():
    t = [0.0]
    hm = HeartbeatMonitor(["a", "b"], timeout=5.0, clock=lambda: t[0])
    t[0] = 10.0
    assert set(hm.sweep()) == {"a", "b"}
    hm.rejoin("a")
    assert hm.alive == ["a"]
    hm.beat("b")                       # dead workers can't silently beat
    assert "b" in hm.dead
