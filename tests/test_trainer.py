"""Trainer integration: loss goes down, checkpoint/restart determinism,
failure injection → recovery, metric logging."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.fault import FaultInjector, HeartbeatMonitor, RestartPolicy
from repro.train import MetricLogger, TrainConfig, Trainer


def make_problem(seed=0):
    """Tiny regression LM-alike: learn y = x @ w_true."""
    rng = np.random.default_rng(seed)
    w_true = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)

    def init_params(key):
        return {"w": jnp.zeros((8, 4), jnp.float32)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        l = jnp.mean((pred - batch["y"]) ** 2)
        return l, {"mse": l}

    def batches(seed=0):
        r = np.random.default_rng(seed)
        while True:
            x = jnp.asarray(r.standard_normal((16, 8)), jnp.float32)
            yield {"x": x, "y": x @ w_true}

    return init_params, loss_fn, batches


def test_loss_decreases():
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init, TrainConfig(lr=0.05, warmup_steps=5, weight_decay=0.0,
                                            total_steps=60, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, batches(), steps=60, logger=logger)
    assert logger.history[-1]["loss"] < 0.05 * logger.history[0]["loss"]


def test_caller_owned_generator_survives_staged_fits():
    """fit() must not close a caller-owned generator: staged training
    resumes consuming the SAME stream across fit() calls (guards both
    the close() ownership check and _chain_first's non-delegating
    abandonment)."""
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init, TrainConfig(lr=0.05, warmup_steps=5,
                                            weight_decay=0.0,
                                            total_steps=40, log_every=1))
    state = tr.init_state(jax.random.PRNGKey(0))
    stream = batches()
    logger = MetricLogger(log_fn=lambda *_: None)
    state, logger = tr.fit(state, stream, steps=15, logger=logger)
    state, logger = tr.fit(state, stream, steps=40, logger=logger)
    assert int(np.asarray(state.step)) == 40


def test_resume_is_deterministic(tmp_path):
    """run 40 steps straight  ≡  run 20, 'crash', restore, run 20."""
    init, loss_fn, batches = make_problem()

    def fit(ckpt_dir, stop_at, resume=False):
        tr = Trainer(loss_fn, init,
                     TrainConfig(lr=0.05, warmup_steps=5, total_steps=40,
                                 ckpt_dir=ckpt_dir, ckpt_every=20,
                                 log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))
        start = 0
        if resume:
            state, start = tr.maybe_restore(state)
        # a restartable batch stream positioned at the right step
        stream = batches()
        for _ in range(start):
            next(stream)
        state, _ = tr.fit(state, stream, steps=stop_at)
        return state

    s_straight = fit(str(tmp_path / "a"), 40)
    fit(str(tmp_path / "b"), 20)
    s_resumed = fit(str(tmp_path / "b"), 40, resume=True)
    np.testing.assert_allclose(np.asarray(s_straight.params["w"]),
                               np.asarray(s_resumed.params["w"]),
                               rtol=1e-6, atol=1e-7)


def test_failure_injection_recovers(tmp_path):
    """A simulated node failure mid-run must restore the last commit and
    still converge."""
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=60,
                             weight_decay=0.0,
                             ckpt_dir=str(tmp_path), ckpt_every=10,
                             log_every=100))
    state = tr.init_state(jax.random.PRNGKey(0))
    inj = FaultInjector(fail_at_steps=[25])
    logger = MetricLogger()
    state, logger = tr.fit(state, batches(), steps=60, logger=logger,
                           fault_injector=inj)
    assert inj.failures == [25]
    assert int(np.asarray(state.step)) == 60
    # ~5 steps of progress re-done after the restore; still converging
    assert logger.history[-1]["loss"] < 0.3


def test_compressed_grads_still_converge():
    init, loss_fn, batches = make_problem()
    tr = Trainer(loss_fn, init,
                 TrainConfig(lr=0.05, warmup_steps=5, total_steps=80,
                             weight_decay=0.0,
                             log_every=100, compress_grads=True))
    state = tr.init_state(jax.random.PRNGKey(0))
    state, logger = tr.fit(state, batches(), steps=80,
                           logger=MetricLogger())
    assert logger.history[-1]["loss"] < 0.1


def test_microbatched_trainer_matches_full():
    init, loss_fn, batches = make_problem()

    def run(n_micro):
        tr = Trainer(loss_fn, init,
                     TrainConfig(lr=0.05, warmup_steps=5, total_steps=10,
                                 n_micro=n_micro, log_every=100))
        state = tr.init_state(jax.random.PRNGKey(0))
        state, _ = tr.fit(state, batches(), steps=10)
        return np.asarray(state.params["w"])

    np.testing.assert_allclose(run(1), run(4), rtol=1e-5, atol=1e-6)


def test_restart_policy_backoff():
    rp = RestartPolicy(max_restarts=3, base_delay=1.0, max_delay=10.0)
    assert rp.next_delay() == 1.0
    assert rp.next_delay() == 2.0
    rp.record_success()
    assert rp.next_delay() == 1.0
    rp.next_delay(); rp.next_delay()
    assert rp.next_delay() is None      # budget exhausted


def test_heartbeat_rejoin():
    t = [0.0]
    hm = HeartbeatMonitor(["a", "b"], timeout=5.0, clock=lambda: t[0])
    t[0] = 10.0
    assert set(hm.sweep()) == {"a", "b"}
    hm.rejoin("a")
    assert hm.alive == ["a"]
    hm.beat("b")                       # dead workers can't silently beat
    assert "b" in hm.dead
