"""Run a test snippet in a subprocess with a forced host-device count.

jax locks the device count at first backend init, so any test needing
N > 1 devices must run in a fresh interpreter with XLA_FLAGS set before
the import.
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout
