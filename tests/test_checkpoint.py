"""Checkpoint manager: atomicity, keep-k, async, bf16 round-trip,
restore-into-structure."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.checkpoint.manager import latest_step


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": [jnp.zeros((2, 2), jnp.int32),
                             jnp.full((1,), 7.0)]}}


def test_roundtrip(tmp_path):
    t = tree()
    save_tree(t, str(tmp_path), step=3)
    like = jax.tree.map(jnp.zeros_like, t)
    restored, step = restore_tree(str(tmp_path), like)
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, restored)
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_atomicity_tmp_ignored(tmp_path):
    t = tree()
    save_tree(t, str(tmp_path), step=1)
    # simulate a crash mid-save: a stale .tmp dir
    os.makedirs(tmp_path / "step_000000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    cm = CheckpointManager(str(tmp_path))      # purges tmp on startup
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_keep_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, save_interval_steps=1)
    t = tree()
    for s in (1, 2, 3, 4):
        cm.save(t, s, blocking=True)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_000000003", "step_000000004"]


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    t = tree()
    cm.save(t, 10, blocking=False)
    cm.wait()
    restored, step = cm.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 10


def test_shape_mismatch_raises(tmp_path):
    save_tree({"a": jnp.ones((2, 2))}, str(tmp_path), step=1)
    with pytest.raises(ValueError):
        restore_tree(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_missing_leaf_raises(tmp_path):
    save_tree({"a": jnp.ones((2,))}, str(tmp_path), step=1)
    with pytest.raises(KeyError):
        restore_tree(str(tmp_path), {"a": jnp.ones((2,)),
                                     "b": jnp.ones((2,))})


def test_restore_picks_latest(tmp_path):
    save_tree({"a": jnp.zeros((2,))}, str(tmp_path), step=1)
    save_tree({"a": jnp.ones((2,))}, str(tmp_path), step=5)
    restored, step = restore_tree(str(tmp_path), {"a": jnp.zeros((2,))})
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones(2))


def test_should_save_interval(tmp_path):
    cm = CheckpointManager(str(tmp_path), save_interval_steps=50)
    assert not cm.should_save(0)
    assert cm.should_save(50)
    assert not cm.should_save(51)


def test_restore_reshards_to_different_mesh(tmp_path):
    """DESIGN.md §7.5: save on one mesh, restore onto a DIFFERENT mesh
    (elastic down/up-scale) — values lossless, new shardings applied."""
    from tests.util_subproc import run_with_devices
    run_with_devices(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

tree = {{"w": jnp.arange(64.0).reshape(8, 8),
        "b": jnp.arange(8.0)}}

# save from a 4-way data mesh
mesh_a = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))
sharded = jax.device_put(tree, NamedSharding(mesh_a, P("data")))
cm = CheckpointManager(r"{tmp_path}", keep=2)
cm.save(sharded, 7, blocking=True)

# restore onto a 2x2 (data, model) mesh with a different layout
mesh_b = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
like = jax.tree.map(jnp.zeros_like, tree)
restored, step = cm.restore(
    like, sharding_fn=lambda key, leaf:
        NamedSharding(mesh_b, P("data", "model") if leaf.ndim == 2 else P()))
assert step == 7
jax.tree.map(lambda a, b: np.testing.assert_array_equal(
    np.asarray(a), np.asarray(b)), tree, restored)
sh = restored["w"].sharding
assert sh.mesh.shape == {{"data": 2, "model": 2}}, sh
print("RESHARD_OK")
""", n_devices=4)
