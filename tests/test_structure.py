"""Packing invariants (DESIGN.md §7.2): every non-pad slot appears once,
children live at strictly earlier levels, sentinel never read unmasked."""

import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core.structure import (BucketSpec, InputGraph,
                                  balanced_binary_tree, chain, fit_bucket,
                                  from_parent_pointers, pack_batch,
                                  pack_external, random_binary_tree)


def random_forest(seed: int, k: int = 4):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        kind = rng.integers(0, 3)
        if kind == 0:
            out.append(chain(int(rng.integers(1, 12))))
        elif kind == 1:
            out.append(random_binary_tree(int(rng.integers(1, 10)), rng))
        else:
            # random DAG-ish tree via parent pointers
            n = int(rng.integers(1, 10))
            parents = [-1] + [int(rng.integers(0, i)) for i in range(1, n)]
            out.append(from_parent_pointers(parents))
    return out


def test_chain_levels():
    g = chain(5)
    assert list(g.levels()) == [0, 1, 2, 3, 4]
    assert g.roots() == [4]


def test_balanced_tree_shape():
    g = balanced_binary_tree(256)
    assert g.num_nodes == 511            # the paper's 256-leaf tree
    assert int(g.levels().max()) == 8


def test_balanced_tree_requires_pow2():
    with pytest.raises(ValueError):
        balanced_binary_tree(3)


def test_cycle_detection():
    g = InputGraph(children=[[1], [0]])
    with pytest.raises(ValueError):
        g.levels()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_pack_invariants(seed):
    graphs = random_forest(seed)
    sched = pack_batch(graphs)
    T, M, A = sched.T, sched.M, sched.A
    sentinel = T * M

    # 1. every real vertex owns exactly one slot; slot ids unique
    slots = sched.slot_of[sched.node_valid > 0]
    assert len(np.unique(slots)) == len(slots)
    assert int(sched.node_mask.sum()) == sum(g.num_nodes for g in graphs)

    # 2. children strictly earlier levels
    for t in range(T):
        for m in range(M):
            for a in range(A):
                if sched.child_mask[t, m, a] > 0:
                    child = sched.child_ids[t, m, a]
                    assert child < t * M, "child not at earlier level"

    # 3. padding slots point at the sentinel everywhere
    pad = sched.node_mask == 0
    assert np.all(sched.child_ids[pad] == sentinel)
    assert np.all(sched.ext_ids[pad] == sched.num_ext_rows)

    # 4. root slots are valid slots of their sample
    for k, g in enumerate(graphs):
        assert sched.root_slots[k] in sched.slot_of[k][: g.num_nodes]


def test_bucket_padding_reuse():
    rng = np.random.default_rng(1)
    graphs = [random_binary_tree(int(rng.integers(2, 12)), rng)
              for _ in range(32)]
    spec = fit_bucket(graphs, batch_size=4)
    s1 = spec.pack(graphs[:4])
    s2 = spec.pack(graphs[4:8])
    # identical padded dims → identical compiled program
    assert (s1.T, s1.M, s1.A, s1.N) == (s2.T, s2.M, s2.A, s2.N)


def test_bucket_too_small_raises():
    with pytest.raises(ValueError):
        pack_batch([chain(9)], pad_levels=4)


def test_pack_external_rows():
    graphs = [chain(3), chain(2)]
    sched = pack_batch(graphs)
    xs = [np.ones((3, 5), np.float32), 2 * np.ones((2, 5), np.float32)]
    ext = pack_external(xs, sched, 5)
    assert ext.shape == (sched.num_ext_rows + 1, 5)
    assert np.all(ext[-1] == 0)          # sentinel row is zeros
    np.testing.assert_array_equal(ext[0], np.ones(5))
    np.testing.assert_array_equal(ext[sched.N], 2 * np.ones(5))


def test_occupancy_accounting():
    graphs = [chain(4), chain(2)]
    sched = pack_batch(graphs)
    assert 0 < sched.occupancy <= 1.0
    assert sched.occupancy == sched.node_mask.sum() / (sched.T * sched.M)
