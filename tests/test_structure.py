"""Packing invariants (DESIGN.md §7.2): every non-pad slot appears once,
children live at strictly earlier levels, sentinel never read unmasked."""

import numpy as np
import pytest

from tests.hypothesis_compat import given, settings, st

from repro.core.structure import (BucketSpec, InputGraph,
                                  balanced_binary_tree, chain, fit_bucket,
                                  from_parent_pointers, pack_batch,
                                  pack_external, random_binary_tree)


def random_forest(seed: int, k: int = 4):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        kind = rng.integers(0, 3)
        if kind == 0:
            out.append(chain(int(rng.integers(1, 12))))
        elif kind == 1:
            out.append(random_binary_tree(int(rng.integers(1, 10)), rng))
        else:
            # random DAG-ish tree via parent pointers
            n = int(rng.integers(1, 10))
            parents = [-1] + [int(rng.integers(0, i)) for i in range(1, n)]
            out.append(from_parent_pointers(parents))
    return out


def test_chain_levels():
    g = chain(5)
    assert list(g.levels()) == [0, 1, 2, 3, 4]
    assert g.roots() == [4]


def test_balanced_tree_shape():
    g = balanced_binary_tree(256)
    assert g.num_nodes == 511            # the paper's 256-leaf tree
    assert int(g.levels().max()) == 8


def test_balanced_tree_requires_pow2():
    with pytest.raises(ValueError):
        balanced_binary_tree(3)


def test_cycle_detection():
    g = InputGraph(children=[[1], [0]])
    with pytest.raises(ValueError):
        g.levels()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_pack_invariants(seed):
    graphs = random_forest(seed)
    sched = pack_batch(graphs)
    T, M, A = sched.T, sched.M, sched.A
    sentinel = T * M

    # 1. every real vertex owns exactly one slot; slot ids unique
    slots = sched.slot_of[sched.node_valid > 0]
    assert len(np.unique(slots)) == len(slots)
    assert int(sched.node_mask.sum()) == sum(g.num_nodes for g in graphs)

    # 2. children strictly earlier levels
    for t in range(T):
        for m in range(M):
            for a in range(A):
                if sched.child_mask[t, m, a] > 0:
                    child = sched.child_ids[t, m, a]
                    assert child < t * M, "child not at earlier level"

    # 3. padding slots point at the sentinel everywhere
    pad = sched.node_mask == 0
    assert np.all(sched.child_ids[pad] == sentinel)
    assert np.all(sched.ext_ids[pad] == sched.num_ext_rows)

    # 4. root slots are valid slots of their sample
    for k, g in enumerate(graphs):
        assert sched.root_slots[k] in sched.slot_of[k][: g.num_nodes]


def test_bucket_padding_reuse():
    rng = np.random.default_rng(1)
    graphs = [random_binary_tree(int(rng.integers(2, 12)), rng)
              for _ in range(32)]
    spec = fit_bucket(graphs, batch_size=4)
    s1 = spec.pack(graphs[:4])
    s2 = spec.pack(graphs[4:8])
    # identical padded dims → identical compiled program
    assert (s1.T, s1.M, s1.A, s1.N) == (s2.T, s2.M, s2.A, s2.N)


def test_bucket_too_small_raises():
    with pytest.raises(ValueError):
        pack_batch([chain(9)], pad_levels=4)


# ---------------------------------------------------------------------------
# Pad validation: errors name the offending graph; sentinel interaction
# ---------------------------------------------------------------------------

def test_pad_errors_name_offending_graph():
    graphs = [chain(2), chain(9), chain(3)]
    with pytest.raises(ValueError,
                       match=r"pad_levels=4 < required T=9 \(graph 1 has "
                             r"9 levels\)"):
        pack_batch(graphs, pad_levels=4)
    with pytest.raises(ValueError,
                       match=r"pad_nodes=5 < required N=9 \(graph 1 has "
                             r"9 nodes\)"):
        pack_batch(graphs, pad_nodes=5)
    mixed = [chain(4), balanced_binary_tree(4)]
    with pytest.raises(ValueError,
                       match=r"pad_arity=1 < required A=2 \(graph 1 has a "
                             r"vertex of arity 2\)"):
        pack_batch(mixed, pad_arity=1)


def test_pad_width_error_names_widest_level_and_graph():
    # level 0 holds all 4+2=6 leaves; graph 0 contributes 4 of them
    graphs = [balanced_binary_tree(4), balanced_binary_tree(2)]
    with pytest.raises(ValueError,
                       match=r"pad_width=3 < required M=6 \(level 0 is "
                             r"widest; graph 0 alone contributes 4 of its "
                             r"6 slots\)"):
        pack_batch(graphs, pad_width=3)


def test_pad_nodes_and_width_sentinel_interaction():
    """The buffer sentinel is T*M and the external sentinel is K*N —
    BOTH move when pads move.  Every padding slot must point at the
    padded sentinels, and pack_external must place sample rows at the
    padded stride with the zero row at index K*N."""
    graphs = [chain(3), chain(2)]
    s = pack_batch(graphs, pad_levels=5, pad_width=4, pad_nodes=7)
    assert (s.T, s.M, s.N) == (5, 4, 7)
    assert s.sentinel_slot == 20 and s.num_ext_rows == 14
    pad = s.node_mask == 0
    assert np.all(s.child_ids[pad] == 20)
    assert np.all(s.ext_ids[pad] == 14)
    # real slots never reference either sentinel unmasked
    real = s.node_mask > 0
    assert np.all(s.ext_ids[real] < 14)
    assert np.all(s.child_ids[s.child_mask > 0] < 20)
    # sorted runs are over the PADDED [M*A] lanes and stay consistent
    assert s.sort_perm.shape == (5, 4 * s.A)
    np.testing.assert_array_equal(
        np.sort(s.child_ids.reshape(5, -1), axis=1), s.sorted_child_ids)

    xs = [np.ones((3, 2), np.float32), 2 * np.ones((2, 2), np.float32)]
    ext = pack_external(xs, s, 2)
    assert ext.shape == (15, 2)          # K*N + 1 rows at padded N
    np.testing.assert_array_equal(ext[0:3], 1.0)
    np.testing.assert_array_equal(ext[3:7], 0.0)    # sample 0 pad rows
    np.testing.assert_array_equal(ext[7:9], 2.0)    # sample 1 at stride N=7
    np.testing.assert_array_equal(ext[14], 0.0)     # sentinel row


def test_pack_external_overflow_names_sample():
    s = pack_batch([chain(2)], pad_nodes=2)
    with pytest.raises(ValueError,
                       match=r"sample 0 has 3 rows > pad_nodes=2"):
        pack_external([np.zeros((3, 4), np.float32)], s, 4)


def test_pack_external_rows():
    graphs = [chain(3), chain(2)]
    sched = pack_batch(graphs)
    xs = [np.ones((3, 5), np.float32), 2 * np.ones((2, 5), np.float32)]
    ext = pack_external(xs, sched, 5)
    assert ext.shape == (sched.num_ext_rows + 1, 5)
    assert np.all(ext[-1] == 0)          # sentinel row is zeros
    np.testing.assert_array_equal(ext[0], np.ones(5))
    np.testing.assert_array_equal(ext[sched.N], 2 * np.ones(5))


def test_occupancy_accounting():
    graphs = [chain(4), chain(2)]
    sched = pack_batch(graphs)
    assert 0 < sched.occupancy <= 1.0
    assert sched.occupancy == sched.node_mask.sum() / (sched.T * sched.M)
