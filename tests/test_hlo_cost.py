"""The trip-count-aware HLO cost walker — the §Roofline instrument —
validated against XLA's own cost analysis on loop-free programs and
against hand counts on scans/collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost
from tests.util_subproc import run_with_devices


def test_matches_xla_on_loop_free():
    d = 256
    w = jnp.ones((d, d), jnp.float32)

    def f(x):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x

    c = jax.jit(f).lower(jnp.ones((8, d))).compile()
    ours = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.xla_cost_dict(c)
    assert ours.flops == pytest.approx(xla["flops"], rel=0.02)
    assert ours.hbm_bytes == pytest.approx(xla["bytes accessed"], rel=0.02)


def test_scan_trip_multiplication():
    d, n = 128, 17
    w = jnp.ones((d, d), jnp.float32)

    def scanned(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=n)
        return y

    c = jax.jit(scanned).lower(jnp.ones((4, d))).compile()
    ours = hlo_cost.analyze(c.as_text())
    expected_dot = n * 2 * 4 * d * d
    assert ours.flops == pytest.approx(expected_dot, rel=0.05)
    # XLA's own number misses the ×n
    assert hlo_cost.xla_cost_dict(c)["flops"] < ours.flops / (n / 2)


def test_nested_scan():
    d = 64
    w = jnp.ones((d, d), jnp.float32)

    def inner(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=3)
        return y

    def outer(x):
        y, _ = jax.lax.scan(lambda c, _: (inner(c), None), x, None, length=5)
        return y

    c = jax.jit(outer).lower(jnp.ones((2, d))).compile()
    ours = hlo_cost.analyze(c.as_text())
    assert ours.flops == pytest.approx(15 * 2 * 2 * d * d, rel=0.05)


def test_shape_histogram_consistent():
    def f(x):
        return jnp.tanh(x @ jnp.ones((64, 64))) @ jnp.ones((64, 32))

    c = jax.jit(f).lower(jnp.ones((8, 64))).compile()
    ours = hlo_cost.analyze(c.as_text())
    assert sum(ours.by_shape.values()) == pytest.approx(ours.hbm_bytes)


def test_parse_shapes():
    from repro.analysis.hlo_cost import parse_shapes
    s = parse_shapes("(s32[], /*index=1*/bf16[8,256]{1,0}, f32[2,2])")
    assert [x.dtype for x in s] == ["s32", "bf16", "f32"]
    assert s[1].bytes == 8 * 256 * 2
    assert s[0].dims == ()


@pytest.mark.slow
def test_collectives_in_loops():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.analysis import hlo_cost
mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("d",))
w = jnp.ones((256, 256), jnp.float32)
def f(x, w):
    def step(c, _): return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(step, x, None, length=5)
    return jnp.sum(y)
with mesh:
    c = jax.jit(jax.grad(f, argnums=1),
                in_shardings=(NamedSharding(mesh, P("d")), NamedSharding(mesh, P())),
                out_shardings=NamedSharding(mesh, P())).lower(
                    jnp.ones((64, 256)), w).compile()
a = hlo_cost.analyze(c.as_text())
assert abs(a.collectives.get("all-reduce", 0) - 5*256*256*4) < 1e-6, a.collectives
print("OK")
""", n_devices=8)


def test_roofline_terms_and_bottleneck():
    from repro.analysis.roofline import RooflineReport, V5E
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="m", chips=256,
        hlo_flops=1e14, hlo_bytes=1e12, collective_bytes=1e11,
        collective_detail={}, model_flops_total=1e16,
        peak_memory_bytes=1e9)
    assert rep.t_compute == pytest.approx(1e14 / V5E.peak_flops)
    assert rep.t_memory == pytest.approx(1e12 / V5E.hbm_bw)
    assert rep.t_collective == pytest.approx(1e11 / V5E.ici_bw)
    assert rep.bottleneck == "collective"
    assert 0 < rep.mfu_bound <= 1.0 or rep.mfu_bound > 0


def test_model_flops_moe_active():
    from repro.analysis.roofline import model_flops
    assert model_flops(100, 10) == 6000
    assert model_flops(100, 10, active_param_count=25) == 1500
