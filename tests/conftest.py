"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests run on ONE
device; multi-device tests spawn subprocesses (see util_subproc)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
