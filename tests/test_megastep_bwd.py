"""Property-based gradient-parity harness for the fused backward
level-megastep (PR 3 tentpole).

Three independent renderings of the megastep reverse sweep must agree
on every cotangent — parameters, external inputs, and the state chain
(exercised all the way down to the leaf/initial levels by losses over
ALL node states, not just roots):

  1. ``fusion_mode="none"``        — op-by-op grad-through-scan (the
                                     dynamic-declaration oracle);
  2. fused VJP, ``chunked`` impl   — the jnp ``level_bwd`` sweep + XLA
                                     scatter-add (the pre-fusion path,
                                     kept as the ablation baseline);
  3. fused VJP, ``pallas`` impl    — ONE ``bwd_megastep`` launch per
                                     reverse level (interpret mode):
                                     recompute + cotangent math +
                                     duplicate-safe scatter-add fused,
                                     gradient buffer aliased in place.

The sweep is hypothesis-driven over random topologies (var-length
chains, random trees, multi-parent DAGs with duplicate child ids,
singleton levels, ``M=1``) for all four gate kinds, with deterministic
parametrized cases mirroring every topology class so the suite keeps
its coverage when hypothesis is not installed.

Also here: the analytic ``level_bwd``/``level_param_grads`` vs the pure
autodiff oracle (``ref.level_bwd``), the fused kernel vs the ref
reverse step on one level, the row-chunked scatter-add (duplicate
accumulation across panel boundaries), the structural launch census
(exactly one ``pallas_call`` in the forward scan body and one in the
reverse scan body), and the ``fusion_mode="megastep"`` error paths with
their raised MESSAGES asserted.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from tests.hypothesis_compat import given, settings, st

from repro.core.scheduler import (execute, execute_lazy, readout_nodes,
                                  readout_roots)
from repro.core.structure import (chain, pack_batch, pack_external,
                                  random_binary_tree, random_dag)
from repro.core.vertex import LambdaVertex, VertexOutput, get_gate_spec
from repro.kernels import level_megastep as lm
from repro.kernels import level_megastep_bwd as lmb
from repro.kernels import ref
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeFCVertex, TreeLSTMVertex

KINDS = ["lstm", "gru", "treelstm", "treefc", "dag"]


def _make_case(kind, seed, sizes=None, input_dim=4, hidden=4):
    """Pack a batch of random topologies for one gate kind.

    ``sizes``: per-graph node counts; defaults to a var-length draw.
    ``dag`` runs the N-ary Tree-LSTM over multi-parent DAGs — the
    topology class where one level scatters DUPLICATE child ids.
    """
    rng = np.random.default_rng(seed)
    if sizes is None:
        sizes = [int(n) for n in rng.integers(1, 9, size=3)]
    if kind == "lstm":
        fn = LSTMVertex(input_dim=input_dim, hidden=hidden)
        graphs = [chain(n) for n in sizes]
    elif kind == "gru":
        fn = GRUVertex(input_dim=input_dim, hidden=hidden)
        graphs = [chain(n) for n in sizes]
    elif kind == "treelstm":
        fn = TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=2)
        graphs = [random_binary_tree(n, rng) for n in sizes]
    elif kind == "treefc":
        fn = TreeFCVertex(input_dim=input_dim, hidden=hidden)
        graphs = [random_binary_tree(n, rng) for n in sizes]
    else:
        fn = TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=3)
        graphs = [random_dag(max(n, 2), rng, max_arity=3) for n in sizes]
    params = fn.init(jax.random.PRNGKey(seed))
    arity = max(max(g.max_arity for g in graphs), fn.arity, 1)
    sched = pack_batch(graphs, pad_arity=arity)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              * 0.3 for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched.to_device(), ext


def _grads(fn, params, dev, ext, mode, impl, monkeypatch, lazy=False):
    """Params + external cotangents under one (fusion_mode, impl) pair,
    with a loss over ALL node states — every buffer row, including the
    leaf (initial-state) levels, carries a nonzero cotangent."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", impl)

    def loss(p, e):
        if lazy:
            buf = execute_lazy(fn, p, e, dev, fusion_mode=mode)
        else:
            buf = execute(fn, p, dev, e, fusion_mode=mode).buf
        nodes = readout_nodes(buf, dev)
        return jnp.sum(nodes ** 2) + jnp.sum(readout_roots(buf, dev) ** 3)

    return jax.grad(loss, (0, 1))(params, ext)


def _assert_tree_close(a, b, rtol=1e-4, atol=1e-5):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=rtol, atol=atol), a, b)


# ---------------------------------------------------------------------------
# Gradient parity: fused pallas ≡ jnp level_bwd sweep ≡ op-by-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("kind", KINDS)
def test_bwd_parity_var_length(kind, seed, monkeypatch):
    fn, params, dev, ext = _make_case(kind, seed)
    g_none = _grads(fn, params, dev, ext, "none", "chunked", monkeypatch)
    g_jnp = _grads(fn, params, dev, ext, "megastep", "chunked", monkeypatch)
    g_pal = _grads(fn, params, dev, ext, "megastep", "pallas", monkeypatch)
    _assert_tree_close(g_none, g_jnp)
    _assert_tree_close(g_jnp, g_pal)


@pytest.mark.parametrize("kind", ["lstm", "treelstm"])
def test_bwd_parity_singleton_levels_and_m1(kind, monkeypatch):
    """A single chain packs at M=1 — every batching task is a singleton
    (the degenerate schedule the kernel's sorted-run grid must survive:
    n = A contributions, one run each).  The Tree-LSTM variant runs the
    N-ary child-sum cell over the same chain (arity padded to 2, so one
    real + one sentinel child per level)."""
    input_dim = 4
    if kind == "lstm":
        fn = LSTMVertex(input_dim=input_dim, hidden=4)
    else:
        fn = TreeLSTMVertex(input_dim=input_dim, hidden=4, arity=2)
    graphs = [chain(6)]
    params = fn.init(jax.random.PRNGKey(11))
    sched = pack_batch(graphs, pad_arity=max(fn.arity, 1))
    rng = np.random.default_rng(11)
    inputs = [rng.standard_normal((6, input_dim)).astype(np.float32) * 0.3]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    dev = sched.to_device()
    assert dev.M == 1
    g_none = _grads(fn, params, dev, ext, "none", "chunked", monkeypatch)
    g_pal = _grads(fn, params, dev, ext, "megastep", "pallas", monkeypatch)
    _assert_tree_close(g_none, g_pal)


def test_bwd_parity_single_vertex_graphs(monkeypatch):
    """Graphs of one node: T=1, leaves only, every child is the
    sentinel — the reverse sweep is pure seeding, no real scatter."""
    fn, params, dev, ext = _make_case("lstm", 3, sizes=[1, 1, 1])
    assert dev.T == 1
    g_none = _grads(fn, params, dev, ext, "none", "chunked", monkeypatch)
    g_pal = _grads(fn, params, dev, ext, "megastep", "pallas", monkeypatch)
    _assert_tree_close(g_none, g_pal)


@pytest.mark.parametrize("seed", [2, 9])
def test_bwd_parity_duplicate_child_ids(seed, monkeypatch):
    """Multi-parent DAGs: several parents in ONE level gather the same
    child row, so the fused kernel's sorted-run scatter must accumulate
    duplicates exactly like XLA's .at[].add."""
    fn, params, dev, ext = _make_case("dag", seed, sizes=[8, 10, 6])
    cids = np.asarray(dev.child_ids).reshape(dev.T, -1)
    has_dup = any(
        len(np.unique(r[r != dev.T * dev.M])) < np.sum(r != dev.T * dev.M)
        for r in cids)
    assert has_dup, "case must exercise duplicate child ids"
    g_none = _grads(fn, params, dev, ext, "none", "chunked", monkeypatch)
    g_pal = _grads(fn, params, dev, ext, "megastep", "pallas", monkeypatch)
    _assert_tree_close(g_none, g_pal)


@pytest.mark.parametrize("kind", ["gru", "treefc"])
def test_bwd_parity_execute_lazy(kind, monkeypatch):
    """The lazy entry point shares the fused VJP — same parity holds."""
    fn, params, dev, ext = _make_case(kind, 5)
    g_none = _grads(fn, params, dev, ext, "none", "chunked", monkeypatch,
                    lazy=True)
    g_pal = _grads(fn, params, dev, ext, "megastep", "pallas", monkeypatch,
                   lazy=True)
    _assert_tree_close(g_none, g_pal)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(KINDS),
       st.lists(st.integers(1, 12), min_size=1, max_size=4))
def test_bwd_parity_property(seed, kind, sizes):
    """Hypothesis sweep: ANY random topology batch must satisfy the
    three-way gradient parity (fused pallas ≡ jnp sweep ≡ op-by-op)."""
    import os
    fn, params, dev, ext = _make_case(kind, seed, sizes=sizes)

    def loss(p, e, mode):
        buf = execute(fn, p, dev, e, fusion_mode=mode).buf
        return jnp.sum(readout_nodes(buf, dev) ** 2)

    old = os.environ.get("REPRO_KERNEL_IMPL")
    try:
        os.environ["REPRO_KERNEL_IMPL"] = "chunked"
        g_none = jax.grad(lambda p, e: loss(p, e, "none"), (0, 1))(params, ext)
        g_jnp = jax.grad(
            lambda p, e: loss(p, e, "megastep"), (0, 1))(params, ext)
        os.environ["REPRO_KERNEL_IMPL"] = "pallas"
        g_pal = jax.grad(
            lambda p, e: loss(p, e, "megastep"), (0, 1))(params, ext)
    finally:
        if old is None:
            os.environ.pop("REPRO_KERNEL_IMPL", None)
        else:
            os.environ["REPRO_KERNEL_IMPL"] = old
    _assert_tree_close(g_none, g_jnp)
    _assert_tree_close(g_jnp, g_pal)


# ---------------------------------------------------------------------------
# Analytic backward vs pure-autodiff oracle (one level, no scheduler)
# ---------------------------------------------------------------------------

def _level_case(kind, seed, m=5, h=4, a=None):
    rng = np.random.default_rng(seed)
    smult = {"lstm": 2, "treelstm": 2, "gru": 1, "treefc": 1}[kind]
    gmult = {"lstm": 4, "treelstm": 4, "gru": 3, "treefc": 1}[kind]
    a = a if a is not None else (1 if kind in ("lstm", "gru") else 2)
    S, G = smult * h, gmult * h
    child = rng.standard_normal((m, a, S)).astype(np.float32)
    cmask = (rng.random((m, a)) > 0.25).astype(np.float32)
    child *= cmask[..., None]          # masked children gather zeros
    rows = rng.standard_normal((m, G)).astype(np.float32)
    g_state = rng.standard_normal((m, S)).astype(np.float32)
    if kind in ("lstm", "gru"):
        ws = (rng.standard_normal((h, G)).astype(np.float32) * 0.3,
              rng.standard_normal((G,)).astype(np.float32) * 0.1)
    elif kind == "treelstm":
        ws = tuple(rng.standard_normal((h, h)).astype(np.float32) * 0.3
                   for _ in range(4)) \
            + (rng.standard_normal((4 * h,)).astype(np.float32) * 0.1,)
    else:
        ws = (rng.standard_normal((a * h, h)).astype(np.float32) * 0.3,
              rng.standard_normal((h,)).astype(np.float32) * 0.1)
    return (jnp.asarray(g_state), jnp.asarray(child), jnp.asarray(rows),
            jnp.asarray(cmask), tuple(jnp.asarray(w) for w in ws))


@pytest.mark.parametrize("seed", [0, 4])
@pytest.mark.parametrize("kind", ["lstm", "gru", "treelstm", "treefc"])
def test_analytic_level_bwd_matches_autodiff_oracle(kind, seed):
    """``level_megastep.level_bwd`` + ``level_param_grads`` (the math
    the fused kernel runs in VMEM) ≡ jax.vjp through the naive cell
    forward (``ref.level_bwd``) on child, pulled-row AND weight
    cotangents."""
    g_state, child, rows, cmask, ws = _level_case(kind, seed)
    g_child_a, d_gates, aux = lm.level_bwd(kind, g_state, child, rows,
                                           cmask, ws)
    w_grads_a = lm.level_param_grads(kind, d_gates, aux, ws)
    g_child_o, d_rows_o, w_grads_o = ref.level_bwd(kind, g_state, child,
                                                   rows, cmask, ws)
    np.testing.assert_allclose(np.asarray(g_child_a), np.asarray(g_child_o),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d_gates), np.asarray(d_rows_o),
                               rtol=1e-4, atol=1e-5)
    for wa, wo in zip(w_grads_a, w_grads_o):
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wo),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused backward kernel vs ref reverse step (one level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["lstm", "gru", "treelstm", "treefc"])
def test_bwd_megastep_kernel_matches_ref(kind):
    """One reverse level through the Pallas kernel (interpret) ≡ the
    autodiff ref step — duplicate child rows, a sentinel child, a
    masked slot, and bit-exact preservation of every row the level does
    not touch (the in-place alias invariant)."""
    rng = np.random.default_rng(13)
    h = 5
    smult = {"lstm": 2, "treelstm": 2, "gru": 1, "treefc": 1}[kind]
    gmult = {"lstm": 4, "treelstm": 4, "gru": 3, "treefc": 1}[kind]
    a = 1 if kind in ("lstm", "gru") else 2
    S, G = smult * h, gmult * h
    T, M, t = 4, 6, 2
    buf = rng.standard_normal((T * M + 1, S)).astype(np.float32)
    buf[-1] = 0.0
    g = rng.standard_normal((T * M + 1, S)).astype(np.float32)
    cids = rng.integers(0, t * M, size=(M, a)).astype(np.int32)
    cids[0, :] = cids[1, :]                 # duplicates across slots
    cids[2, -1] = T * M                     # sentinel child
    cmask = (cids != T * M).astype(np.float32)
    eids = rng.integers(0, 10, size=(M,)).astype(np.int32)
    ext = rng.standard_normal((11, G)).astype(np.float32)
    nm = np.ones((M,), np.float32)
    nm[-1] = 0.0                            # masked slot
    _, _, _, _, ws = _level_case(kind, 13, m=M, h=h, a=a)
    out_p = lmb.bwd_megastep(kind, jnp.asarray(g), jnp.asarray(buf),
                             jnp.asarray(cids), jnp.asarray(eids),
                             jnp.asarray(nm), jnp.int32(t * M),
                             jnp.asarray(ext), ws, interpret=True)
    out_r = ref.bwd_megastep(kind, jnp.asarray(g), jnp.asarray(buf),
                             jnp.asarray(cids), jnp.asarray(cmask),
                             jnp.asarray(eids), jnp.asarray(nm), t * M,
                             jnp.asarray(ext), ws)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)
    untouched = np.setdiff1d(np.arange(T * M + 1), cids)
    np.testing.assert_array_equal(np.asarray(out_p)[untouched], g[untouched])


# ---------------------------------------------------------------------------
# Row-chunked scatter-add (the ROADMAP VMEM-scaling item)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d,n,block_r,block_d", [
    (40, 10, 30, 8, 512),     # 5 row panels
    (200, 130, 64, 16, 128),  # 13 panels x 2 column stripes
    (9, 6, 5, 4, 512),        # 3 panels, last one ragged
    (64, 8, 128, 8, 8),       # n >> R: every panel hit repeatedly
])
def test_scatter_add_rows_row_chunked(r, d, n, block_r, block_d):
    """A schedule deep enough to force multiple row panels: duplicate
    indices must accumulate identically whether their destination
    shares a panel or not, panel-boundary rows (first/last of a panel)
    included, untouched rows preserved bit-exact."""
    rng = np.random.default_rng(int(r + d + n))
    dst = rng.standard_normal((r, d)).astype(np.float32)
    idx = rng.integers(0, r, size=(n,)).astype(np.int32)
    idx[: n // 3] = idx[0]                  # heavy duplicate accumulation
    idx[-1] = r - 1                         # last row of the last panel
    idx[-2] = block_r - 1                   # last row of panel 0
    idx[-3] = block_r % r                   # first row of panel 1
    rows = rng.standard_normal((n, d)).astype(np.float32)
    out_p = lmb.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows), block_r=block_r,
                                 block_d=block_d, interpret=True)
    out_r = ref.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    untouched = np.setdiff1d(np.arange(r), idx)
    np.testing.assert_array_equal(np.asarray(out_p)[untouched],
                                  dst[untouched])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 80), st.integers(1, 60),
       st.sampled_from([4, 8, 16, 1024]))
def test_scatter_add_rows_property(seed, r, n, block_r):
    """Any (R, n, panel size): kernel ≡ XLA scatter-add."""
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 20))
    dst = rng.standard_normal((r, d)).astype(np.float32)
    idx = rng.integers(0, r, size=(n,)).astype(np.int32)
    rows = rng.standard_normal((n, d)).astype(np.float32)
    out_p = lmb.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows), block_r=block_r,
                                 interpret=True)
    out_r = ref.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Structural launch census: 1 pallas launch per level, fwd AND bwd
# ---------------------------------------------------------------------------

# Promoted to a runtime surface in PR 9; the tests pin the same walker
# the profiler ships.
from repro.obs.profile import walk_jaxpr as _walk_jaxpr  # noqa: E402


@pytest.mark.parametrize("kind", ["lstm", "treelstm"])
def test_reverse_sweep_is_one_launch_per_level(kind, monkeypatch):
    """The acceptance criterion, asserted on the traced program: under
    the pallas backend the grad jaxpr contains exactly TWO scans — the
    forward megastep scan and the reverse sweep — each carrying exactly
    ONE pallas_call in its body (scan body = one level), and no
    pallas_call anywhere else (the flat lazy param pass is plain jnp).
    """
    fn, params, dev, ext = _make_case(kind, 1)

    def loss(p, e):
        buf = execute(fn, p, dev, e, fusion_mode="megastep").buf
        return jnp.sum(readout_roots(buf, dev) ** 2)

    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1)))(params, ext)
    scans, outside = [], [0]
    _walk_jaxpr(jaxpr.jaxpr, scans, outside)
    assert scans == [1, 1], (
        f"expected one pallas launch per scan body (fwd megastep + rev "
        f"bwd_megastep), got per-scan counts {scans}")
    assert outside[0] == 0, (
        f"{outside[0]} pallas_call(s) outside the level scans — the flat "
        f"param pass and readouts must stay kernel-free")

    # The oracle path is kernel-free end to end.
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "chunked")
    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1)))(params, ext)
    scans, outside = [], [0]
    _walk_jaxpr(jaxpr.jaxpr, scans, outside)
    assert scans == [0, 0] and outside[0] == 0


# ---------------------------------------------------------------------------
# fusion_mode="megastep" error paths: messages, not just types
# ---------------------------------------------------------------------------

def _plain_vertex():
    return LambdaVertex(
        state_dim=3, ext_dim=2, arity=1,
        init_fn=lambda rng: {"w": jnp.zeros((2, 3))},
        apply_fn=lambda p, io: VertexOutput(state=io.pull() @ p["w"]),
        project_fn=lambda p, raw: raw)


def _tiny_sched(n=3, ext_dim=2, pad_arity=2):
    sched = pack_batch([chain(n)], pad_arity=pad_arity)
    ext = jnp.asarray(pack_external([np.ones((n, ext_dim), np.float32)],
                                    sched, ext_dim))
    return sched.to_device(), ext


def test_megastep_error_no_gate_spec_message():
    """A cell without a GateSpec: the error must name every failed
    requirement and echo the offending configuration."""
    fn = _plain_vertex()
    params = fn.init(jax.random.PRNGKey(0))
    dev, ext = _tiny_sched()
    with pytest.raises(
            ValueError,
            match=r"fusion_mode='megastep' needs a cell with a GateSpec "
                  r"and an eager projection, hoist=True, collect_push=False "
                  r"and a float32 buffer dtype \(got fn=LambdaVertex, "
                  r"hoist=True, collect_push=False, "):
        execute(fn, params, dev, ext, fusion_mode="megastep")


def test_megastep_error_wrong_arity_message():
    """Tree-FC packed at the wrong arity: the error must name the cell,
    both arities, and the two remedies (repack or fall back)."""
    fn = TreeFCVertex(input_dim=2, hidden=3)          # arity 2
    params = fn.init(jax.random.PRNGKey(0))
    dev, ext = _tiny_sched(pad_arity=1)               # chains pack at A=1
    with pytest.raises(
            ValueError,
            match=r"fusion_mode='megastep': TreeFCVertex declares a fixed "
                  r"gather arity 2 but the packed schedule has A=1 — repack "
                  r"with pad_arity=2 or use fusion_mode='none'"):
        execute(fn, params, dev, ext, fusion_mode="megastep")


def test_megastep_error_hoist_and_push_messages():
    """hoist=False / collect_push=True each disqualify fusion, and the
    message reports the actual flag values."""
    fn = LSTMVertex(input_dim=2, hidden=3)
    params = fn.init(jax.random.PRNGKey(0))
    dev, ext = _tiny_sched()
    with pytest.raises(ValueError, match=r"hoist=False, collect_push=False"):
        execute(fn, params, dev, ext, hoist=False, fusion_mode="megastep")
    with pytest.raises(ValueError, match=r"hoist=True, collect_push=True"):
        execute(fn, params, dev, ext, collect_push=True,
                fusion_mode="megastep")


def test_megastep_error_bad_mode_and_dtype_messages():
    fn = LSTMVertex(input_dim=2, hidden=3)
    params = fn.init(jax.random.PRNGKey(0))
    dev, ext = _tiny_sched()
    with pytest.raises(ValueError,
                       match=r"fusion_mode must be 'auto', 'megastep' or "
                             r"'none', got 'sometimes'"):
        execute(fn, params, dev, ext, fusion_mode="sometimes")
    with pytest.raises(ValueError, match=r"float32 buffer dtype"):
        execute(fn, params, dev, ext, dtype=jnp.bfloat16,
                fusion_mode="megastep")
    # Under "auto" the same configurations silently take the op-by-op
    # path instead of raising.
    assert get_gate_spec(fn) is not None
    r = execute(fn, params, dev, ext, dtype=jnp.bfloat16, fusion_mode="auto")
    assert r.buf.dtype == jnp.bfloat16
