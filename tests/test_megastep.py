"""Fused level-megastep equivalences (no hypothesis dependency — this
file must always collect and run):

  - fused ``execute``/``execute_lazy`` ≡ the op-by-op scan ≡
    ``execute_serial`` on forward states, for var-length chains
    (LSTM, GRU), random binary trees (Tree-LSTM, Tree-FC) and
    multi-parent DAGs (N-ary Tree-LSTM);
  - fused custom-VJP gradients (params AND external) ≡ grad through the
    unfused scan, to 1e-4;
  - the Pallas kernels (interpret mode) ≡ the ``ref.py`` oracle on a
    single batching task, including sentinel children, masked slots and
    in-place preservation of all untouched buffer rows;
  - the Pallas scatter-add backward (``level_megastep_bwd``) ≡ the jnp
    reverse sweep, standalone (duplicate indices) and end-to-end;
  - ``fusion_mode`` plumbing: "none" vs "megastep" vs "auto", the
    required-fusion error for cells without a GateSpec, and the
    fixed-arity fallback (Tree-FC on a mismatched schedule).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.scheduler import (execute, execute_lazy, execute_serial,
                                  readout_nodes, readout_roots)
from repro.core.structure import (chain, pack_batch, pack_external,
                                  random_binary_tree, random_dag)
from repro.core.vertex import LambdaVertex, VertexOutput
from repro.kernels import level_megastep as lm
from repro.kernels import level_megastep_bwd as lmb
from repro.kernels import ref
from repro.models.rnn import GRUVertex, LSTMVertex
from repro.models.treelstm import TreeFCVertex, TreeLSTMVertex


def _case(kind, seed, input_dim=6, hidden=5):
    rng = np.random.default_rng(seed)
    if kind == "lstm":
        fn = LSTMVertex(input_dim=input_dim, hidden=hidden)
        graphs = [chain(int(n)) for n in rng.integers(1, 12, size=4)]
    elif kind == "gru":
        fn = GRUVertex(input_dim=input_dim, hidden=hidden)
        graphs = [chain(int(n)) for n in rng.integers(1, 12, size=4)]
    elif kind == "treelstm":
        fn = TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=2)
        graphs = [random_binary_tree(int(n), rng)
                  for n in rng.integers(1, 10, size=4)]
    elif kind == "treefc":
        fn = TreeFCVertex(input_dim=input_dim, hidden=hidden)
        graphs = [random_binary_tree(int(n), rng)
                  for n in rng.integers(1, 10, size=4)]
    else:  # multi-parent DAGs (Fig. 2d) through the N-ary cell
        fn = TreeLSTMVertex(input_dim=input_dim, hidden=hidden, arity=3)
        graphs = [random_dag(int(n), rng, max_arity=3)
                  for n in rng.integers(2, 12, size=3)]
    params = fn.init(jax.random.PRNGKey(seed))
    arity = max(max(g.max_arity for g in graphs), fn.arity, 1)
    sched = pack_batch(graphs, pad_arity=arity)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              * 0.3 for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, graphs, inputs, sched, ext


KINDS = ["lstm", "gru", "treelstm", "treefc", "dag"]


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kind", KINDS)
def test_fused_forward_equals_unfused_and_serial(kind, seed):
    fn, params, graphs, inputs, sched, ext = _case(kind, seed)
    dev = sched.to_device()
    r_un = execute(fn, params, dev, ext, fusion_mode="none")
    r_fu = execute(fn, params, dev, ext, fusion_mode="megastep")
    np.testing.assert_allclose(np.asarray(r_fu.buf), np.asarray(r_un.buf),
                               rtol=1e-4, atol=1e-5)
    nodes = np.asarray(readout_nodes(r_fu.buf, dev))
    serial = execute_serial(fn, params, graphs, inputs)
    for k, g in enumerate(graphs):
        np.testing.assert_allclose(nodes[k, : g.num_nodes], serial[k],
                                   rtol=2e-5, atol=2e-5)
    # the sentinel row is never written by any megastep
    np.testing.assert_array_equal(np.asarray(r_fu.buf[-1]), 0.0)


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("kind", KINDS)
def test_fused_grads_equal_unfused(kind, seed):
    """The fused custom VJP (scatter-add sweep + flat lazy param pass)
    must match grad-through-scan on params and external inputs."""
    fn, params, _, _, sched, ext = _case(kind, seed)
    dev = sched.to_device()

    def loss(p, e, mode):
        r = execute(fn, p, dev, e, fusion_mode=mode)
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    g_un = jax.grad(lambda p, e: loss(p, e, "none"), (0, 1))(params, ext)
    g_fu = jax.grad(lambda p, e: loss(p, e, "megastep"), (0, 1))(params, ext)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g_un, g_fu)


@pytest.mark.parametrize("kind", ["lstm", "gru", "treelstm", "treefc"])
def test_fused_lazy_matches_opbyop_lazy(kind):
    fn, params, _, _, sched, ext = _case(kind, 5)
    dev = sched.to_device()
    b_un = execute_lazy(fn, params, ext, dev, fusion_mode="none")
    b_fu = execute_lazy(fn, params, ext, dev, fusion_mode="megastep")
    np.testing.assert_allclose(np.asarray(b_fu), np.asarray(b_un),
                               rtol=1e-4, atol=1e-5)

    def loss(p, e, mode):
        return jnp.sum(readout_roots(
            execute_lazy(fn, p, e, dev, fusion_mode=mode), dev) ** 2)

    g_un = jax.grad(lambda p, e: loss(p, e, "none"), (0, 1))(params, ext)
    g_fu = jax.grad(lambda p, e: loss(p, e, "megastep"), (0, 1))(params, ext)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g_un, g_fu)


def test_fused_jit_roundtrip():
    """The fused path must trace/jit cleanly (scan-carried buffer)."""
    fn, params, _, _, sched, ext = _case("treelstm", 7)
    dev = sched.to_device()
    f = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                     fusion_mode="megastep").buf)
    g = jax.jit(lambda p, e: execute(fn, p, dev, e, fusion_mode="none").buf)
    np.testing.assert_allclose(np.asarray(f(params, ext)),
                               np.asarray(g(params, ext)),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs ref oracle
# ---------------------------------------------------------------------------

def _level_fixture(seed, M=6, H=8, T=4, A=1, n_ext=10):
    rng = np.random.default_rng(seed)
    S = 2 * H
    buf = rng.standard_normal((T * M + 1, S)).astype(np.float32)
    buf[-1] = 0.0                                 # sentinel row
    t = 2
    cids = rng.integers(0, t * M, size=(M, A)).astype(np.int32)
    cids[0, -1] = T * M                           # one sentinel child
    cmask = (cids != T * M).astype(np.float32)
    eids = rng.integers(0, n_ext, size=(M,)).astype(np.int32)
    ext = rng.standard_normal((n_ext + 1, 4 * H)).astype(np.float32)
    nm = np.ones((M,), np.float32)
    nm[-1] = 0.0                                  # one padded slot
    return (jnp.asarray(buf), jnp.asarray(cids), jnp.asarray(cmask),
            jnp.asarray(eids), jnp.asarray(nm), t * M, jnp.asarray(ext), rng)


@pytest.mark.parametrize("seed,m,h", [(0, 6, 8), (1, 3, 16), (2, 9, 4)])
def test_lstm_megastep_kernel_matches_ref(seed, m, h):
    buf, cids, cmask, eids, nm, off, ext, rng = _level_fixture(seed, M=m, H=h)
    wh = jnp.asarray(rng.standard_normal((h, 4 * h)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((4 * h,)) * 0.1, jnp.float32)
    out_p = lm.lstm_megastep(buf, cids, eids, nm, jnp.int32(off), ext, wh, b,
                             interpret=True)
    out_r = ref.level_megastep("lstm", buf, cids, cmask, eids, nm, off, ext,
                               (wh, b))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-6, atol=2e-6)
    # in-place alias: every row outside [off, off+m) is preserved bit-exact
    np.testing.assert_array_equal(np.asarray(out_p[:off]),
                                  np.asarray(buf[:off]))
    np.testing.assert_array_equal(np.asarray(out_p[off + m:]),
                                  np.asarray(buf[off + m:]))


@pytest.mark.parametrize("seed,m,h,a", [(0, 6, 8, 2), (1, 5, 4, 3)])
def test_treelstm_megastep_kernel_matches_ref(seed, m, h, a):
    buf, cids, cmask, eids, nm, off, ext, rng = _level_fixture(
        seed, M=m, H=h, A=a)
    ws = [jnp.asarray(rng.standard_normal((h, h)) * 0.2, jnp.float32)
          for _ in range(4)]
    b = jnp.asarray(rng.standard_normal((4 * h,)) * 0.1, jnp.float32)
    out_p = lm.treelstm_megastep(buf, cids, eids, nm, jnp.int32(off), ext,
                                 *ws, b, interpret=True)
    out_r = ref.level_megastep("treelstm", buf, cids, cmask, eids, nm, off,
                               ext, tuple(ws) + (b,))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(out_p[:off]),
                                  np.asarray(buf[:off]))


@pytest.mark.parametrize("seed,m,h", [(0, 6, 8), (1, 3, 16)])
def test_gru_megastep_kernel_matches_ref(seed, m, h):
    rng = np.random.default_rng(seed)
    T, A = 4, 1
    buf = rng.standard_normal((T * m + 1, h)).astype(np.float32)
    buf[-1] = 0.0
    t = 2
    cids = rng.integers(0, t * m, size=(m, A)).astype(np.int32)
    cids[0, -1] = T * m                           # one sentinel child
    cmask = (cids != T * m).astype(np.float32)
    eids = rng.integers(0, 10, size=(m,)).astype(np.int32)
    ext = jnp.asarray(rng.standard_normal((11, 3 * h)), jnp.float32)
    nm = np.ones((m,), np.float32)
    nm[-1] = 0.0
    wh = jnp.asarray(rng.standard_normal((h, 3 * h)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((3 * h,)) * 0.1, jnp.float32)
    out_p = lm.gru_megastep(jnp.asarray(buf), jnp.asarray(cids),
                            jnp.asarray(eids), jnp.asarray(nm),
                            jnp.int32(t * m), ext, wh, b, interpret=True)
    out_r = ref.level_megastep("gru", jnp.asarray(buf), jnp.asarray(cids),
                               jnp.asarray(cmask), jnp.asarray(eids),
                               jnp.asarray(nm), t * m, ext, (wh, b))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(out_p[:t * m]), buf[:t * m])
    np.testing.assert_array_equal(np.asarray(out_p[t * m + m:]),
                                  buf[t * m + m:])


@pytest.mark.parametrize("seed,m,h,a", [(0, 6, 8, 2), (1, 5, 4, 3)])
def test_treefc_megastep_kernel_matches_ref(seed, m, h, a):
    rng = np.random.default_rng(seed)
    T = 4
    buf = rng.standard_normal((T * m + 1, h)).astype(np.float32)
    buf[-1] = 0.0
    t = 2
    cids = rng.integers(0, t * m, size=(m, a)).astype(np.int32)
    cids[0, -1] = T * m
    cmask = (cids != T * m).astype(np.float32)
    eids = rng.integers(0, 10, size=(m,)).astype(np.int32)
    ext = jnp.asarray(rng.standard_normal((11, h)), jnp.float32)
    nm = np.ones((m,), np.float32)
    nm[-1] = 0.0
    wc = jnp.asarray(rng.standard_normal((a * h, h)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((h,)) * 0.1, jnp.float32)
    out_p = lm.treefc_megastep(jnp.asarray(buf), jnp.asarray(cids),
                               jnp.asarray(eids), jnp.asarray(nm),
                               jnp.int32(t * m), ext, wc, b, interpret=True)
    out_r = ref.level_megastep("treefc", jnp.asarray(buf), jnp.asarray(cids),
                               jnp.asarray(cmask), jnp.asarray(eids),
                               jnp.asarray(nm), t * m, ext, (wc, b))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(out_p[:t * m]), buf[:t * m])
    np.testing.assert_array_equal(np.asarray(out_p[t * m + m:]),
                                  buf[t * m + m:])


# ---------------------------------------------------------------------------
# Pallas scatter-add backward (level_megastep_bwd) vs jnp reverse sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,r,d,n", [(0, 20, 10, 16), (1, 9, 130, 5),
                                        (2, 33, 256, 40)])
def test_scatter_add_rows_kernel_matches_ref(seed, r, d, n):
    """The backward memory op: duplicates must accumulate (∂gather =
    scatter-add for multi-parent DAGs), untouched rows preserved."""
    rng = np.random.default_rng(seed)
    dst = rng.standard_normal((r, d)).astype(np.float32)
    idx = rng.integers(0, r, size=(n,)).astype(np.int32)
    idx[n // 2] = idx[0]                          # force a duplicate
    rows = rng.standard_normal((n, d)).astype(np.float32)
    out_p = lmb.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows), interpret=True)
    out_r = ref.scatter_add_rows(jnp.asarray(dst), jnp.asarray(idx),
                                 jnp.asarray(rows))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)
    untouched = np.setdiff1d(np.arange(r), idx)
    np.testing.assert_array_equal(np.asarray(out_p)[untouched],
                                  dst[untouched])


@pytest.mark.parametrize("kind", ["lstm", "gru", "treelstm", "treefc", "dag"])
def test_pallas_backward_matches_jnp_sweep(kind, monkeypatch):
    """End-to-end: the fused backward with the PALLAS scatter-add kernel
    (interpret mode) ≡ the same sweep through XLA's .at[].add oracle.
    The DAG case exercises duplicate child indices within one level."""
    fn, params, _, _, sched, ext = _case(kind, 17, input_dim=4, hidden=4)
    dev = sched.to_device()

    def loss(p, e):
        r = execute(fn, p, dev, e, fusion_mode="megastep")
        return jnp.sum(readout_roots(r.buf, dev) ** 2)

    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    g_pal = jax.grad(loss, (0, 1))(params, ext)
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "chunked")
    g_jnp = jax.grad(loss, (0, 1))(params, ext)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g_pal, g_jnp)


def test_scheduler_pallas_megastep_matches_unfused(monkeypatch):
    """End-to-end: the scheduler's fused scan with the PALLAS backend
    (interpret mode on CPU) ≡ the unfused op-by-op scan."""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
    fn, params, _, _, sched, ext = _case("treelstm", 11, input_dim=4,
                                         hidden=4)
    dev = sched.to_device()
    r_fu = execute(fn, params, dev, ext, fusion_mode="megastep")
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "chunked")
    r_un = execute(fn, params, dev, ext, fusion_mode="none")
    np.testing.assert_allclose(np.asarray(r_fu.buf), np.asarray(r_un.buf),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fusion_mode plumbing
# ---------------------------------------------------------------------------

def test_fusion_mode_auto_uses_megastep_and_env_disables(monkeypatch):
    fn, params, _, _, sched, ext = _case("lstm", 13)
    dev = sched.to_device()
    r_auto = execute(fn, params, dev, ext)                  # default: auto
    monkeypatch.setenv("REPRO_FUSION", "none")
    r_env_off = execute(fn, params, dev, ext)
    np.testing.assert_allclose(np.asarray(r_auto.buf),
                               np.asarray(r_env_off.buf),
                               rtol=1e-5, atol=1e-6)


def test_fusion_mode_megastep_requires_gate_spec():
    # A cell with no gate_spec() declaration stays on the op-by-op path.
    fn = LambdaVertex(
        state_dim=3, ext_dim=2, arity=1,
        init_fn=lambda rng: {"w": jnp.zeros((2, 3))},
        apply_fn=lambda p, io: VertexOutput(state=io.pull() @ p["w"]),
        project_fn=lambda p, raw: raw)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch([chain(3)], pad_arity=2)
    ext = jnp.asarray(pack_external([np.ones((3, 2), np.float32)], sched, 2))
    dev = sched.to_device()
    with pytest.raises(ValueError, match="GateSpec"):
        execute(fn, params, dev, ext, fusion_mode="megastep")
    # hoist=False also disqualifies the fused path
    fn2 = LSTMVertex(input_dim=2, hidden=3)
    with pytest.raises(ValueError, match="hoist"):
        execute(fn2, fn2.init(jax.random.PRNGKey(0)), dev,
                jnp.zeros((4, 2)), hoist=False, fusion_mode="megastep")


def test_fusion_mode_treefc_arity_mismatch(monkeypatch):
    """Tree-FC's concat weight fixes the gather arity: a schedule packed
    at a different A must raise under "megastep" and resolve to the
    op-by-op path (spec None) under "auto"."""
    from repro.core.scheduler import resolve_fusion
    monkeypatch.delenv("REPRO_FUSION", raising=False)   # CI matrix sets it
    fn = TreeFCVertex(input_dim=2, hidden=3)          # arity 2
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch([chain(3)])                    # chains pack at A=1
    ext = jnp.asarray(pack_external([np.ones((3, 2), np.float32)], sched, 2))
    dev = sched.to_device()
    with pytest.raises(ValueError, match="arity"):
        execute(fn, params, dev, ext, fusion_mode="megastep")
    assert resolve_fusion(fn, "auto", sched_arity=1) is None
    assert resolve_fusion(fn, "auto", sched_arity=2) is not None
    assert resolve_fusion(fn, "auto", sched_arity=2).kind == "treefc"
