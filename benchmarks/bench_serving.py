"""Serving under load (beyond-paper): continuous union-frontier
batching vs request-granularity flushing.

A seeded Poisson arrival trace of variable-length chain requests is
replayed in real time against both serving paths:

  - **baseline** — :class:`StructureServeEngine`: each flush packs the
    queued requests into one depth-padded batch and scores it whole
    (request-granularity batching: admission only at flush boundaries,
    every member padded to the deepest co-batched graph);
  - **continuous** — :class:`ContinuousBatchEngine`: one live frontier
    over all in-flight graphs, mid-flight admission into freed arena
    rows, multi-tick dispatch windows, per-topology plan-cache reuse.

Reported per path: p50/p99 end-to-end latency (submit → terminal) and
completed-request throughput over the trace makespan.  With
``--assert-parity`` the continuous results are additionally checked
BIT-IDENTICAL to scoring every request alone — the smoke-CI gate that
the throughput win never comes from changed numerics.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Collector
from repro.core.structure import chain
from repro.models.rnn import LSTMVertex
from repro.serve import (AdmissionPolicy, ContinuousBatchEngine,
                         ContinuousRequest, StructureRequest,
                         StructureServeEngine)


def _poisson_trace(seed: int, n: int, rate_hz: float, lengths):
    """Seeded Poisson arrivals: (arrival_s, chain_len) per request."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    arrivals = np.cumsum(gaps)
    lens = rng.choice(np.asarray(lengths), size=n)
    return arrivals, lens, rng


def _make_requests(cls, arrivals, lens, rng, input_dim):
    reqs = []
    for i, L in enumerate(lens):
        x = rng.standard_normal((int(L), input_dim)).astype(np.float32) * 0.3
        reqs.append(cls(request_id=i, graph=chain(int(L)), inputs=x))
    return reqs


def _replay(engine, reqs, arrivals, max_wall_s: float = 300.0):
    """Replay the trace in real time: submit each request at its arrival
    offset, stepping the engine in between.  Returns (latencies_s,
    makespan_s) over completed requests."""
    n = len(reqs)
    t0 = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t0
        while i < n and arrivals[i] <= now:
            engine.submit(reqs[i])
            i += 1
        live = engine.step()
        if i >= n and live == 0:
            break
        if live == 0 and i < n:
            time.sleep(min(0.001, max(0.0, arrivals[i] - (time.monotonic()
                                                          - t0))))
        if time.monotonic() - t0 > max_wall_s:
            raise RuntimeError("trace replay exceeded wall budget")
    makespan = time.monotonic() - t0
    lats = [r._finished_at - r._enqueued_at for r in reqs
            if r.status == "ok"]
    n_ok = sum(r.status == "ok" for r in reqs)
    assert n_ok == n, f"only {n_ok}/{n} requests completed ok"
    return np.asarray(lats), makespan


def _warm(engine_factory, reqs_factory, k: int = 6):
    """Compile-warm a fresh engine on a tiny preamble so the measured
    replay sees steady-state (bucketed shapes already traced)."""
    eng = engine_factory()
    for r in reqs_factory(k):
        eng.submit(r)
    eng.run()
    return eng


def _assert_parity(fn, params, reqs, fusion_mode: str) -> None:
    """Every continuous result must be bitwise the solo score."""
    solo = StructureServeEngine(fn, params, batch_size=1, compose=False,
                                fusion_mode=fusion_mode)
    checks = [StructureRequest(r.request_id, r.graph, r.inputs)
              for r in reqs]
    for c in checks:
        assert solo.submit(c), c.error
    solo.run()
    for r, c in zip(reqs, checks):
        assert c.status == "ok", (c.status, c.error)
        if not np.array_equal(r.root_state, c.root_state):
            raise AssertionError(
                f"parity violation: request {r.request_id} continuous "
                f"root != solo root (mode={fusion_mode})")


def main(argv=None) -> Collector:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized single config (default when not --full)")
    ap.add_argument("--assert-parity", action="store_true",
                    help="fail unless continuous results are bit-identical "
                         "to solo scoring")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    col = Collector()
    if args.full:
        n, rate = 400, 300.0
        hidden, input_dim = 96, 48
    else:
        n, rate = 120, 250.0
        hidden, input_dim = 64, 32
    lengths = (4, 6, 8, 12, 16, 24, 32)
    batch_size = 16

    fn = LSTMVertex(input_dim=input_dim, hidden=hidden)
    params = fn.init(jax.random.PRNGKey(0))
    fusion_mode = "auto"

    arrivals, lens, rng = _poisson_trace(args.seed, n, rate, lengths)

    def baseline_factory():
        return StructureServeEngine(fn, params, batch_size=batch_size,
                                    fusion_mode=fusion_mode)

    def continuous_factory():
        return ContinuousBatchEngine(
            fn, params, num_rows=1024, frontier_width=64,
            fusion_mode=fusion_mode,
            policy=AdmissionPolicy(min_occupancy=0.0, max_window=8))

    def warm_reqs_struct(k):
        g = np.random.default_rng(99)
        return [StructureRequest(1000 + j, chain(int(L)),
                                 g.standard_normal((int(L), input_dim))
                                 .astype(np.float32))
                for j, L in enumerate(list(lengths)[:k])]

    def warm_reqs_cont(k):
        g = np.random.default_rng(99)
        return [ContinuousRequest(1000 + j, chain(int(L)),
                                  g.standard_normal((int(L), input_dim))
                                  .astype(np.float32))
                for j, L in enumerate(list(lengths)[:k])]

    results = {}
    for name, factory, cls, warm_reqs in (
            ("baseline", baseline_factory, StructureRequest,
             warm_reqs_struct),
            ("continuous", continuous_factory, ContinuousRequest,
             warm_reqs_cont)):
        eng = _warm(factory, warm_reqs, k=len(lengths))
        reqs = _make_requests(cls, arrivals, lens,
                              np.random.default_rng(args.seed + 1),
                              input_dim)
        lats, makespan = _replay(eng, reqs, arrivals)
        p50 = float(np.percentile(lats, 50) * 1e3)
        p99 = float(np.percentile(lats, 99) * 1e3)
        thr = len(lats) / makespan
        det = (f"n={n} rate={rate}/s lens={min(lengths)}-{max(lengths)} "
               f"h={hidden}")
        col.add(f"serving/{name}_p50_latency", p50, "ms", det)
        col.add(f"serving/{name}_p99_latency", p99, "ms", det)
        col.add(f"serving/{name}_throughput", thr, "req/s", det)
        results[name] = {"p50": p50, "p99": p99, "thr": thr,
                         "reqs": reqs, "eng": eng}

    gain = results["continuous"]["thr"] / results["baseline"]["thr"]
    p99_ratio = results["continuous"]["p99"] / results["baseline"]["p99"]
    col.add("serving/continuous_throughput_gain", gain, "x",
            "continuous vs request-granularity flushing, same trace")
    col.add("serving/continuous_p99_ratio", p99_ratio, "x",
            "continuous p99 / baseline p99 (<= 1 is better-or-equal)")
    h = results["continuous"]["eng"].health()
    col.add("serving/continuous_plan_hit_rate",
            h["plan_hits"] / max(1, h["plan_hits"] + h["plan_misses"]),
            "frac", f"windows={h['windows']} ticks={h['ticks']}")

    if args.assert_parity:
        _assert_parity(fn, params, results["continuous"]["reqs"],
                       fusion_mode)
        col.add("serving/parity_bit_identical", 1.0, "bool",
                "every continuous root bitwise equals solo scoring")

    return col


if __name__ == "__main__":
    c = main()
    for rec in c.records:
        print(",".join(str(rec[k]) for k in ("name", "value", "unit")))
