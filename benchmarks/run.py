"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run``          — quick CPU settings (CI-sized)
``python -m benchmarks.run --full``   — the paper-scale sweeps

Emits ``name,value,unit,detail`` CSV rows (captured into
bench_output.txt by the top-level runs).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_ablation, bench_fixed_lstm,
                        bench_graph_construction, bench_memory,
                        bench_roofline, bench_tree_fc, bench_tree_lstm,
                        bench_var_lstm)

SUITES = [
    ("fixed_lstm (Fig 8a/e)", bench_fixed_lstm),
    ("var_lstm (Fig 8b/f)", bench_var_lstm),
    ("tree_fc (Fig 8c/g, Tab 1)", bench_tree_fc),
    ("tree_lstm (Fig 8d/h, Tab 1-2)", bench_tree_lstm),
    ("graph_construction (Fig 9)", bench_graph_construction),
    ("memory (Tab 2)", bench_memory),
    ("ablation (Fig 10)", bench_ablation),
    ("roofline (beyond-paper)", bench_roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on suite names")
    args = ap.parse_args()

    print("suite,name,value,unit,detail")
    failures = 0
    for title, mod in SUITES:
        if args.only and args.only not in title:
            continue
        print(f"# === {title} ===", flush=True)
        t0 = time.time()
        try:
            mod.main(["--full"] if args.full else [])
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SUITE FAILED: {title}", flush=True)
            traceback.print_exc()
        print(f"# --- {title} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
