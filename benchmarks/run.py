"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run``          — quick CPU settings (CI-sized)
``python -m benchmarks.run --full``   — the paper-scale sweeps

Emits ``name,value,unit,detail`` CSV rows (captured into
bench_output.txt by the top-level runs) AND, per suite, a
machine-readable ``results/BENCH_<suite>.json`` with one record per row
(value + mean/p50 stats for timed rows, fused vs. unfused megastep
measurements included) — the perf trajectory tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_ablation, bench_dist, bench_fixed_lstm,
                        bench_graph_construction, bench_memory,
                        bench_roofline, bench_serving, bench_tree_fc,
                        bench_tree_lstm, bench_var_lstm)
from benchmarks.common import add_stage_rows, emit_pipeline_stages
from repro.obs import trace
from repro.obs.registry import fresh_registry

SUITES = [
    ("fixed_lstm (Fig 8a/e)", bench_fixed_lstm),
    ("var_lstm (Fig 8b/f)", bench_var_lstm),
    ("tree_fc (Fig 8c/g, Tab 1)", bench_tree_fc),
    ("tree_lstm (Fig 8d/h, Tab 1-2)", bench_tree_lstm),
    ("graph_construction (Fig 9)", bench_graph_construction),
    ("memory (Tab 2)", bench_memory),
    ("ablation (Fig 10)", bench_ablation),
    ("roofline (beyond-paper)", bench_roofline),
    ("serving (beyond-paper)", bench_serving),
    ("dist (beyond-paper)", bench_dist),
]


def _suite_slug(title: str) -> str:
    head = title.split()[0]
    return "".join(ch for ch in head if ch.isalnum() or ch == "_")


def _dump_json(title: str, col, out_dir: str, elapsed_s: float) -> None:
    records = getattr(col, "records", None)
    if not records:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{_suite_slug(title)}.json")
    with open(path, "w") as f:
        json.dump({"suite": title, "elapsed_s": round(elapsed_s, 2),
                   "rows": records}, f, indent=1)
    print(f"# wrote {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on suite names")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_<suite>.json records")
    args = ap.parse_args()

    print("suite,name,value,unit,detail")
    failures = 0
    for title, mod in SUITES:
        if args.only and args.only not in title:
            continue
        print(f"# === {title} ===", flush=True)
        t0 = time.time()
        try:
            # Per-suite tracer + registry: any instrumented path the
            # suite exercises (pipeline, serving, kernels) feeds the
            # registry's span.* histograms; emit_pipeline_stages then
            # guarantees the core compose→pack→fwd→bwd stages exist
            # even for suites that bypass SchedulePipeline, and the
            # aggregate becomes stage/<name> rows in BENCH_<suite>.json.
            with fresh_registry() as reg, \
                    trace.install_tracer(trace.Tracer(registry=reg)):
                col = mod.main(["--full"] if args.full else [])
                emit_pipeline_stages()
                add_stage_rows(col, reg)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SUITE FAILED: {title}", flush=True)
            traceback.print_exc()
        else:
            _dump_json(title, col, args.out_dir, time.time() - t0)
        print(f"# --- {title} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
