"""Beyond-paper: the roofline table from the multi-pod dry-run.

Reads ``results/dryrun.jsonl`` (produced by ``repro.launch.dryrun``)
and prints the per-(arch × shape × mesh) three-term roofline rows that
EXPERIMENTS.md §Roofline embeds.
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import Collector


def load(path: str):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    # keep the newest row per cell
    latest = {}
    for r in rows:
        latest[(r["arch"], r["shape"], r.get("mesh"))] = r
    return list(latest.values())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="results/dryrun.jsonl")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    rows = load(args.path)
    if not rows:
        col.add("roofline/missing", 0, "n/a",
                "run `python -m repro.launch.dryrun --all` first")
        return col
    ok = err = skip = 0
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         str(r.get("mesh")))):
        cell = f"{r['arch']}|{r['shape']}|{r.get('mesh')}"
        if r["status"] == "skip":
            skip += 1
            continue
        if r["status"] != "ok":
            err += 1
            col.add(f"roofline/{cell}/ERROR", 0, "n/a",
                    str(r.get("error", ""))[:80])
            continue
        ok += 1
        col.add(f"roofline/{cell}/t_bound", r["t_bound_s"], "s",
                f"bottleneck={r['bottleneck']}")
        col.add(f"roofline/{cell}/mfu_bound", r["mfu_bound"], "frac", "")
    col.add("roofline/cells_ok", ok, "count", f"err={err} skip={skip}")
    return col


if __name__ == "__main__":
    main()
