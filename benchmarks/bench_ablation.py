"""Paper Fig. 10: ablations of the three §3.5 engine optimizations.

  - lazy batching : ``execute_lazy`` (one flat parameter-grad VJP) vs
                    grad-through-scan;
  - streaming     : eager-prefix hoisting on vs off (the W·x projection
                    inside vs outside the sequential region);
  - fusion        : kernel-launch census of the fused cell vs the
                    per-op dataflow (the structural evidence; on TPU the
                    pallas cell fuses ~10 elementwise launches into 1 —
                    wall-clock shown in interpret mode is meaningless on
                    CPU, so we report launch counts like the paper
                    reports kernel counts).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.fusion import count_hlo_kernels
from repro.core.scheduler import execute, execute_lazy, readout_roots
from repro.core.structure import pack_batch, pack_external


def setup(model: str, bs: int, hidden: int, rng):
    m = get_paper_model(model)
    fn = m.make_vertex(hidden=hidden, input_dim=64)
    graphs = m.make_graphs(bs, rng=rng) if model != "fixed_lstm" \
        else m.make_graphs(bs, steps=32)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=max(fn.arity, 1))
    inputs = [rng.standard_normal((g.num_nodes, 64)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, 64))
    return fn, params, sched.to_device(), ext


def bench(col: Collector, models, bs: int = 32, hidden: int = 64):
    rng = np.random.default_rng(0)
    for model in models:
        fn, params, dev, ext = setup(model, bs, hidden, rng)

        # ---- lazy batching ---------------------------------------------
        def loss_scan(p, e):
            r = execute(fn, p, dev, e)
            return jnp.sum(readout_roots(r.buf, dev) ** 2)

        def loss_lazy(p, e):
            return jnp.sum(readout_roots(execute_lazy(fn, p, e, dev),
                                         dev) ** 2)

        g_scan = jax.jit(jax.grad(loss_scan))
        g_lazy = jax.jit(jax.grad(loss_lazy))
        t_scan = time_fn(lambda: g_scan(params, ext))
        t_lazy = time_fn(lambda: g_lazy(params, ext))
        col.add(f"ablation/{model}/bwd_scan", t_scan * 1e3, "ms",
                f"bs={bs} h={hidden}")
        col.add(f"ablation/{model}/bwd_lazy", t_lazy * 1e3, "ms",
                f"bs={bs} h={hidden}")
        col.add(f"ablation/{model}/lazy_speedup", t_scan / t_lazy, "x",
                "paper Fig.10 reports ~1.2x")

        # ---- streaming / hoisting ---------------------------------------
        f_on = jax.jit(lambda p, e: execute(fn, p, dev, e, hoist=True).buf)
        f_off = jax.jit(lambda p, e: execute(fn, p, dev, e, hoist=False).buf)
        t_on = time_fn(lambda: f_on(params, ext))
        t_off = time_fn(lambda: f_off(params, ext))
        col.add(f"ablation/{model}/hoist_on", t_on * 1e3, "ms", "")
        col.add(f"ablation/{model}/hoist_off", t_off * 1e3, "ms", "")
        col.add(f"ablation/{model}/stream_speedup", t_off / t_on, "x",
                "eager W·x hoisted out of the sequential region")

        # ---- fusion: kernel-launch census --------------------------------
        comp_on = jax.jit(lambda p, e: execute(
            fn, p, dev, e).buf).lower(params, ext).compile()
        counts = count_hlo_kernels(comp_on.as_text())
        launches = sum(v for k, v in counts.items() if k != "other")
        col.add(f"ablation/{model}/hlo_kernels", launches, "kernels",
                f"while-body+entry launch-sites after XLA fusion")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, models=("fixed_lstm", "tree_lstm", "graph_rnn"), bs=64,
              hidden=256)
    else:
        bench(col, models=("tree_lstm", "graph_rnn"), bs=16, hidden=64)
    return col


if __name__ == "__main__":
    main()
