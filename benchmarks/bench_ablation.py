"""Paper Fig. 10: ablations of the three §3.5 engine optimizations.

  - lazy batching : ``execute_lazy`` (one flat parameter-grad VJP) vs
                    grad-through-scan;
  - streaming     : eager-prefix hoisting on vs off (the W·x projection
                    inside vs outside the sequential region);
  - fusion        : kernel-launch census of the fused cell vs the
                    per-op dataflow (the structural evidence; on TPU the
                    pallas cell fuses ~10 elementwise launches into 1 —
                    wall-clock shown in interpret mode is meaningless on
                    CPU, so we report launch counts like the paper
                    reports kernel counts).

Beyond-paper: the **level-megastep** ablation — each batching task as
ONE fused launch (gather + cell + contiguous block scatter, in-place
buffer; ``fusion_mode="megastep"``) vs the op-by-op scan
(``fusion_mode="none"``).  Wall-clock is reported for both (on CPU the
fused forward lowers to its jnp twin, so treat it as advisory); the
accelerator evidence is structural: launches per level (1 fused vs the
measured while-body census) and modeled HBM bytes per level
(``level_megastep.level_traffic_bytes`` — the gathered child states and
the gate tensor never round-trip in the fused path).

NOTE: every baseline row here pins ``fusion_mode="none"`` — under the
default ``"auto"`` the scheduler would silently fuse and the ablation
would compare the fused path against itself.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn, time_stats
from repro.configs.paper import get_paper_model
from repro.core.fusion import count_hlo_kernels
from repro.core.scheduler import execute, execute_lazy, readout_roots
from repro.core.structure import pack_batch, pack_external
from repro.core.vertex import get_gate_spec
from repro.kernels.level_megastep import (level_bwd_traffic_bytes,
                                          level_traffic_bytes)


def setup(model: str, bs: int, hidden: int, rng):
    m = get_paper_model(model)
    fn = m.make_vertex(hidden=hidden, input_dim=64)
    if model == "fixed_lstm":
        graphs = m.make_graphs(bs, steps=32)
    elif model == "tree_fc":
        graphs = m.make_graphs(bs, leaves=64, rng=rng)   # CI-sized trees
    else:
        graphs = m.make_graphs(bs, rng=rng)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=max(fn.arity, 1))
    inputs = [rng.standard_normal((g.num_nodes, 64)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, 64))
    return fn, params, sched.to_device(), ext


def bench(col: Collector, models, bs: int = 32, hidden: int = 64):
    rng = np.random.default_rng(0)
    for model in models:
        fn, params, dev, ext = setup(model, bs, hidden, rng)

        # ---- lazy batching ---------------------------------------------
        def loss_scan(p, e):
            r = execute(fn, p, dev, e, fusion_mode="none")
            return jnp.sum(readout_roots(r.buf, dev) ** 2)

        def loss_lazy(p, e):
            return jnp.sum(readout_roots(
                execute_lazy(fn, p, e, dev, fusion_mode="none"), dev) ** 2)

        g_scan = jax.jit(jax.grad(loss_scan))
        g_lazy = jax.jit(jax.grad(loss_lazy))
        t_scan = time_fn(lambda: g_scan(params, ext))
        t_lazy = time_fn(lambda: g_lazy(params, ext))
        col.add(f"ablation/{model}/bwd_scan", t_scan * 1e3, "ms",
                f"bs={bs} h={hidden}")
        col.add(f"ablation/{model}/bwd_lazy", t_lazy * 1e3, "ms",
                f"bs={bs} h={hidden}")
        col.add(f"ablation/{model}/lazy_speedup", t_scan / t_lazy, "x",
                "paper Fig.10 reports ~1.2x")

        # ---- streaming / hoisting ---------------------------------------
        f_on = jax.jit(lambda p, e: execute(fn, p, dev, e, hoist=True,
                                            fusion_mode="none").buf)
        f_off = jax.jit(lambda p, e: execute(fn, p, dev, e, hoist=False,
                                             fusion_mode="none").buf)
        t_on = time_fn(lambda: f_on(params, ext))
        t_off = time_fn(lambda: f_off(params, ext))
        col.add(f"ablation/{model}/hoist_on", t_on * 1e3, "ms", "")
        col.add(f"ablation/{model}/hoist_off", t_off * 1e3, "ms", "")
        col.add(f"ablation/{model}/stream_speedup", t_off / t_on, "x",
                "eager W·x hoisted out of the sequential region")

        # ---- fusion: kernel-launch census --------------------------------
        comp_on = jax.jit(lambda p, e: execute(
            fn, p, dev, e, fusion_mode="none").buf).lower(
                params, ext).compile()
        counts = count_hlo_kernels(comp_on.as_text())
        launches = sum(v for k, v in counts.items() if k != "other")
        col.add(f"ablation/{model}/hlo_kernels", launches, "kernels",
                f"while-body+entry launch-sites after XLA fusion")

        # ---- level-megastep: fused single-launch task vs op-by-op scan --
        spec = get_gate_spec(fn)
        if spec is not None:
            det = f"bs={bs} h={hidden}"
            fwd_un = jax.jit(lambda p, e: execute(
                fn, p, dev, e, fusion_mode="none").buf)
            fwd_fu = jax.jit(lambda p, e: execute(
                fn, p, dev, e, fusion_mode="megastep").buf)
            st_un = time_stats(lambda: fwd_un(params, ext))
            st_fu = time_stats(lambda: fwd_fu(params, ext))
            col.add_time(f"ablation/{model}/fwd_unfused", st_un, det)
            col.add_time(f"ablation/{model}/fwd_megastep", st_fu, det)
            col.add(f"ablation/{model}/megastep_fwd_speedup",
                    st_un["p50_ms"] / st_fu["p50_ms"], "x",
                    "CPU wall-clock advisory; see hbm/launch rows")

            def loss_fused(p, e):
                r = execute(fn, p, dev, e, fusion_mode="megastep")
                return jnp.sum(readout_roots(r.buf, dev) ** 2)

            g_fused = jax.jit(jax.grad(loss_fused))
            st_gun = time_stats(lambda: g_scan(params, ext))
            st_gfu = time_stats(lambda: g_fused(params, ext))
            col.add_time(f"ablation/{model}/train_unfused", st_gun, det)
            col.add_time(f"ablation/{model}/train_megastep", st_gfu, det)
            col.add(f"ablation/{model}/megastep_train_speedup",
                    st_gun["p50_ms"] / st_gfu["p50_ms"], "x",
                    "fused fwd + scatter-add sweep + flat lazy param VJP")

            # structural accelerator evidence: launches and HBM traffic
            # per batching task (the fused path is ONE pallas launch by
            # construction; unfused = measured while-body census).
            per_level = max(1, launches - 2) / max(1, dev.T)
            S, H, A = spec.state_dim, spec.hidden, dev.A
            b_un = level_traffic_bytes(spec.kind, dev.M, A, S, H,
                                       fused=False)
            b_fu = level_traffic_bytes(spec.kind, dev.M, A, S, H,
                                       fused=True)
            col.add(f"ablation/{model}/launches_per_level_unfused",
                    per_level, "kernels", "measured HLO census / T")
            col.add(f"ablation/{model}/launches_per_level_megastep", 1,
                    "kernels", "structural: one pallas_call per task")
            col.add(f"ablation/{model}/hbm_bytes_per_level_unfused", b_un,
                    "B", f"M={dev.M} A={A} S={S}")
            col.add(f"ablation/{model}/hbm_bytes_per_level_megastep", b_fu,
                    "B", "child+ext rows read once, state block written")
            col.add(f"ablation/{model}/megastep_hbm_reduction",
                    b_un / b_fu, "x", "modeled HBM round-trips per level")

            # Train direction (PR 3): the reverse sweep is now ONE
            # fused launch per level too (bwd_megastep: recompute +
            # cotangent math + scatter-add, grad buffer aliased) vs the
            # jnp level_bwd sandwiched between memory-op launches.
            gb_un = level_bwd_traffic_bytes(spec.kind, dev.M, A, S, H,
                                            fused=False)
            gb_fu = level_bwd_traffic_bytes(spec.kind, dev.M, A, S, H,
                                            fused=True)
            comp_g = g_scan.lower(params, ext).compile()
            g_counts = count_hlo_kernels(comp_g.as_text())
            g_launches = sum(v for k, v in g_counts.items() if k != "other")
            # Grad HLO has two while loops (fwd replay + reverse): a
            # per-level census divides by 2T.
            col.add(f"ablation/{model}/bwd_launches_per_level_unfused",
                    max(1, g_launches - 2) / max(1, 2 * dev.T), "kernels",
                    "measured grad-HLO census / 2T (fwd replay + reverse)")
            col.add(f"ablation/{model}/bwd_launches_per_level_megastep", 1,
                    "kernels", "structural: one bwd_megastep launch per "
                    "reverse level")
            col.add(f"ablation/{model}/bwd_hbm_bytes_per_level_unfused",
                    gb_un, "B", f"M={dev.M} A={A} S={S}")
            col.add(f"ablation/{model}/bwd_hbm_bytes_per_level_megastep",
                    gb_fu, "B", "child rows+g_state read once, only "
                    "touched dst rows r/w (sorted runs)")
            col.add(f"ablation/{model}/bwd_megastep_hbm_reduction",
                    gb_un / gb_fu, "x",
                    "modeled HBM round-trips per reverse level")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, models=("fixed_lstm", "tree_lstm", "tree_fc",
                           "graph_rnn"), bs=64, hidden=256)
    else:
        bench(col, models=("tree_lstm", "tree_fc", "graph_rnn"), bs=16,
              hidden=64)
    return col


if __name__ == "__main__":
    main()
