"""Compare fresh ``BENCH_<suite>.json`` records against a baseline dir.

The perf-trajectory gate for CI: ``benchmarks/run.py`` writes one JSON
per suite with per-row ``p50_ms`` stats; this script matches rows by
``(suite, name, detail)`` and reports any timed row whose fresh p50
regressed more than ``--threshold`` (default 20%).

Exit status is 0 with warnings by default (CI shared runners are noisy
— the warnings are a review signal, not a hard gate); ``--strict``
exits 1 when regressions are found.

Usage::

    python -m benchmarks.run --out-dir fresh_results
    python benchmarks/compare.py --baseline results --fresh fresh_results
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Tuple

Key = Tuple[str, str, str]


def _load(dirname: str) -> Dict[Key, dict]:
    rows: Dict[Key, dict] = {}
    for path in sorted(glob.glob(os.path.join(dirname, "BENCH_*.json"))):
        with open(path) as f:
            doc = json.load(f)
        for rec in doc.get("rows", []):
            rows[(doc.get("suite", path), rec["name"],
                  rec.get("detail", ""))] = rec
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="results",
                    help="directory with committed BENCH_<suite>.json")
    ap.add_argument("--fresh", required=True,
                    help="directory with freshly generated records")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="relative p50 regression that triggers a warning")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when regressions are found")
    ap.add_argument("--require-stages", default=None, metavar="SUBSTR",
                    help="exit 1 unless fresh suites matching SUBSTR "
                         "contain at least one stage/<name> breakdown row")
    args = ap.parse_args(argv)

    base = _load(args.baseline)
    fresh = _load(args.fresh)

    if args.require_stages is not None:
        hit = any(args.require_stages in suite and
                  name.startswith("stage/")
                  for (suite, name, _detail) in fresh)
        if not hit:
            print(f"compare: no stage/ rows in fresh suites matching "
                  f"{args.require_stages!r} — observability breakdown "
                  f"missing", file=sys.stderr)
            return 1
    if not base:
        print(f"compare: no baseline records under {args.baseline!r} — "
              f"nothing to diff")
        return 0
    if not fresh:
        print(f"compare: no fresh records under {args.fresh!r}",
              file=sys.stderr)
        return 1

    compared = regressions = missing = 0
    for key, b in sorted(base.items()):
        if "p50_ms" not in b:
            continue                       # structural row, not timed
        f = fresh.get(key)
        if f is None or "p50_ms" not in f:
            missing += 1
            continue
        compared += 1
        ratio = f["p50_ms"] / max(b["p50_ms"], 1e-9)
        if ratio > 1.0 + args.threshold:
            regressions += 1
            suite, name, detail = key
            print(f"WARNING: {name} [{detail}] p50 {b['p50_ms']:.3f} -> "
                  f"{f['p50_ms']:.3f} ms ({ratio:.2f}x) in {suite}")
    print(f"compare: {compared} timed rows diffed, {regressions} regressed "
          f">{args.threshold:.0%}, {missing} baseline rows missing fresh "
          f"measurements")
    if regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
