"""Paper Fig. 9: graph construction/preprocessing overhead vs
computation — plus the schedule-compilation pipeline that removes it.

Cavs reads the input graph "through I/O": per minibatch the only
structure work is the host-side level packing (pure NumPy).  The
dynamic-declaration tax is re-TRACING the program per batch (Fold's
preprocessing / DyNet's per-sample graph build); we measure it as
jax re-trace + re-compile time of the same step.

Outputs both axes of Fig. 9: absolute seconds and the fraction of the
total step the structure work takes.

The ``pipeline/*`` rows measure the schedule pipeline (PR 4): packs/sec
cold (``pack_batch`` from scratch) vs on the fingerprint-cache hit path
(acceptance: ≥5x), and compiled-shape counts tight vs bucketed over a
stream of random minibatches.  ``--assert-cache`` additionally enforces
the CI cache-effectiveness gate: a second epoch over the same synthetic
corpus must hit ≥90%.

The ``splice/*`` rows measure the per-graph tier (PR 10): packs/sec of
SPLICING unseen batch combinations out of harvested solo schedules vs
cold-packing them, on a Zipf-weighted corpus where every batch
fingerprint is new but every member graph has been seen — plus the
per-graph warm-restart leg.  ``--assert-splice`` enforces the CI gate
(≥3x forward-path speedup, all combos spliced with zero packs,
byte-identity on a sample, warm restart packs nothing).

The ``composer/*`` rows measure pipeline-aware batch FORMATION (PR 5)
on a skewed synthetic corpus (a few hot topologies + a long tail,
shuffled arrival order — the real-corpus shape): measured cache hit
rate, mean padded occupancy and compile count of FIFO slicing vs
``BatchComposer`` composition over one epoch.  ``--assert-compose``
enforces the CI gate: composed must strictly beat FIFO on hit rate AND
occupancy with compile count no worse.  ``--persist-dir`` routes the
composed leg through an on-disk schedule store; with ``--assert-warm``
the run must be served entirely from the store (zero ``pack_batch``
calls — the warm-restart acceptance check, run as the second of two CI
invocations against the same directory).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.scheduler import execute
from repro.core.structure import (fit_bucket, pack_batch, pack_external,
                                  random_binary_tree)
from repro.pipeline import (BatchComposer, BucketPolicy, ScheduleCache,
                            SchedulePipeline, ShapeCensus)


def bench(col: Collector, leaves_list, bs: int = 16, hidden: int = 32):
    m = get_paper_model("tree_fc")
    fn = m.make_vertex(hidden=hidden, input_dim=32)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for leaves in leaves_list:
        graphs = m.make_graphs(bs, leaves=leaves)
        inputs = [rng.standard_normal((g.num_nodes, 32)).astype(np.float32)
                  for g in graphs]

        # --- Cavs: host-side packing only -----------------------------
        t0 = time.perf_counter()
        sched = pack_batch(graphs, pad_arity=2)
        ext_np = pack_external(inputs, sched, 32)
        t_pack = time.perf_counter() - t0

        dev = sched.to_device()
        ext = jnp.asarray(ext_np)
        run = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
        t_compute = time_fn(lambda: run(params, ext))
        col.add("graphcons/cavs_pack", t_pack * 1e3, "ms",
                f"leaves={leaves} bs={bs}")
        col.add("graphcons/cavs_compute", t_compute * 1e3, "ms",
                f"leaves={leaves} bs={bs}")
        col.add("graphcons/cavs_overhead_frac",
                t_pack / (t_pack + t_compute), "frac",
                f"leaves={leaves} (paper: Fold wastes 0.5-0.8 here)")

        # --- dynamic declaration: re-trace per batch -------------------
        def redeclare():
            f = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
            return f(params, ext)

        t_total_re = time_fn(redeclare, warmup=0, iters=2)
        t_construct = max(t_total_re - t_compute, 0.0)
        col.add("graphcons/redeclare_construct", t_construct * 1e3, "ms",
                f"leaves={leaves} (trace+compile per batch)")
        col.add("graphcons/redeclare_overhead_frac",
                t_construct / max(t_total_re, 1e-12), "frac",
                f"leaves={leaves}")


def _mean_pack_seconds(pack_once, n_batches: int, repeats: int = 3) -> float:
    """Mean seconds per pack over ``repeats`` sweeps of ``n_batches``."""
    best = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for i in range(n_batches):
            pack_once(i)
        best.append((time.perf_counter() - t0) / n_batches)
    return float(np.median(best))


def bench_pipeline(col: Collector, *, n_topologies: int = 24, bs: int = 16,
                   epochs: int = 3, assert_cache: bool = False):
    """Schedule-pipeline rows: cache-hit vs cold packs/sec, hit rate
    over repeated epochs, and tight-vs-bucketed compile counts."""
    rng = np.random.default_rng(0)
    # A synthetic corpus of batches whose topologies REPEAT across
    # epochs (the real-corpus property the cache exploits).
    corpus = []
    for _ in range(n_topologies):
        corpus.append([random_binary_tree(int(rng.integers(4, 24)), rng)
                       for _ in range(bs)])

    # --- cold vs cache-hit packs/sec ----------------------------------
    cold = ScheduleCache(enabled=False)
    t_cold = _mean_pack_seconds(lambda i: cold.get_or_pack(corpus[i]),
                                len(corpus))
    warm = ScheduleCache(enabled=True)
    for g in corpus:
        warm.get_or_pack(g)              # populate
    t_hit = _mean_pack_seconds(lambda i: warm.get_or_pack(corpus[i]),
                               len(corpus))
    col.add("pipeline/cold_packs_per_s", 1.0 / t_cold, "packs/s",
            f"bs={bs} pack_batch from scratch")
    col.add("pipeline/cachehit_packs_per_s", 1.0 / t_hit, "packs/s",
            f"bs={bs} fingerprint lookup")
    speedup = t_cold / t_hit
    col.add("pipeline/cachehit_speedup", speedup, "x",
            f"acceptance: >=5x (got {speedup:.1f}x)")

    # --- cache effectiveness over epochs (the CI gate) ----------------
    pipe = SchedulePipeline(1, bucket_policy=BucketPolicy())
    for _ in range(epochs):
        for g in corpus:
            pipe.cache.get_or_pack(g, pipe.pads_for(g))
    epoch2 = ScheduleCache(enabled=True)
    for g in corpus:
        epoch2.get_or_pack(g)
    epoch2.reset_stats()
    for g in corpus:                      # the second epoch, isolated
        epoch2.get_or_pack(g)
    col.add("pipeline/epoch2_hit_rate", epoch2.hit_rate, "frac",
            f"{n_topologies} batches, identical corpus")
    col.add("pipeline/steady_hit_rate", pipe.cache.hit_rate, "frac",
            f"{epochs} epochs x {n_topologies} batches")
    if assert_cache and epoch2.hit_rate < 0.9:
        raise AssertionError(
            f"cache-effectiveness gate: second-epoch hit rate "
            f"{epoch2.hit_rate:.2f} < 0.90")

    # --- tight vs bucketed compile counts -----------------------------
    tight_census, bucket_census = ShapeCensus(), ShapeCensus()
    policy = BucketPolicy(mode="pow2")
    for g in corpus:
        tight_census.record(pack_batch(g))
        bucket_census.record(pack_batch(g, *policy.bucket(g)))
    col.add("pipeline/compile_count_tight", tight_census.num_shapes,
            "programs", f"{n_topologies} minibatches, tight pads")
    col.add("pipeline/compile_count_bucketed", bucket_census.num_shapes,
            "programs", f"{n_topologies} minibatches, pow2 buckets")

    # --- lazy sorted runs: forward-only schedule size ------------------
    # Serving pipelines pack with_runs=False (no backward ⇒ no
    # sort_perm/sorted_child_ids/run_head): this row is the measured
    # cache/persist entry-size ratio that buys.
    from repro.pipeline.persist import _encode
    full_b = sum(len(_encode(pack_batch(g, with_runs=True)))
                 for g in corpus)
    fwd_b = sum(len(_encode(pack_batch(g, with_runs=False)))
                for g in corpus)
    col.add("pipeline/forward_only_size_frac", fwd_b / full_b, "frac",
            f"with_runs=False entry bytes / full ({n_topologies} batches)")


def bench_splice(col: Collector, *, n_topologies: int = 24, bs: int = 16,
                 n_combos: int = 16, assert_splice: bool = False):
    """``splice/*`` rows (PR 10): packs/sec of the per-graph tier's
    SPLICE path vs a cold ``pack_batch`` on a Zipf-weighted corpus of
    UNSEEN batch combinations — every batch fingerprint is new, but
    every member graph was seen (harvested) earlier — plus the
    per-graph warm-restart leg (a fresh cache splicing straight from
    per-graph disk entries).  ``--assert-splice`` enforces the CI gate:
    forward-path splice ≥3x cold pack, every combo spliced (zero
    ``pack_batch`` executions), a sampled combo byte-identical to the
    monolithic pack, and a warm restart that packs nothing."""
    rng = np.random.default_rng(0)
    topos = [random_binary_tree(int(rng.integers(32, 128)), rng)
             for _ in range(n_topologies)]
    zipf = 1.0 / np.arange(1, n_topologies + 1) ** 1.2
    zipf /= zipf.sum()
    combos, seen = [], set()
    while len(combos) < n_combos:
        idx = tuple(int(i) for i in rng.choice(n_topologies, bs, p=zipf))
        if idx in seen:
            continue
        seen.add(idx)
        combos.append([topos[i] for i in idx])

    def sweep(make_cache, with_runs, repeats=5):
        ts, last = [], None
        for _ in range(repeats):
            cache = make_cache()
            t0 = time.perf_counter()
            for c in combos:
                cache.get_or_pack(c, with_runs=with_runs)
            ts.append((time.perf_counter() - t0) / len(combos))
            last = cache
        return float(np.median(ts)), last

    import tempfile
    with tempfile.TemporaryDirectory() as pdir:
        def seeded():
            """A cache whose GRAPH tier holds every topology (one K=1
            cold pack each) but whose BATCH tier has seen none of the
            combos — the post-first-epoch steady state.  Memory-only:
            the disk tier gets its own warm-restart leg below."""
            cache = ScheduleCache(enabled=True, persist=False)
            for g in topos:
                cache.get_or_pack([g], with_runs=False)
            cache.reset_stats()
            return cache

        t_cold_f, _ = sweep(lambda: ScheduleCache(enabled=False), False)
        t_cold_r, _ = sweep(lambda: ScheduleCache(enabled=False), True)
        t_spl_f, warm_f = sweep(seeded, False)
        t_spl_r, _ = sweep(seeded, True)

        col.add("splice/cold_packs_per_s", 1.0 / t_cold_f, "packs/s",
                f"bs={bs} forward-only pack_batch from scratch")
        col.add("splice/splice_packs_per_s", 1.0 / t_spl_f, "packs/s",
                f"bs={bs} unseen combos assembled from the graph tier")
        fwd_x = t_cold_f / t_spl_f
        col.add("splice/speedup_forward", fwd_x, "x",
                f"with_runs=False (serving path) — gate: >=3x "
                f"(got {fwd_x:.1f}x)")
        col.add("splice/speedup_training", t_cold_r / t_spl_r, "x",
                "with_runs=True (the sorted-run argsort is paid by "
                "both legs)")
        s = warm_f.stats()
        col.add("splice/combo_splices", s["splices"], "splices",
                f"{n_combos} unseen combos, packs={s['packs']}")

        # --- per-graph warm restart: a FRESH process, same store ------
        seed_disk = ScheduleCache(enabled=True, persist=pdir)
        for g in topos:
            seed_disk.get_or_pack([g], with_runs=False)  # harvest → disk
        restart = ScheduleCache(enabled=True, persist=pdir)
        for c in combos[: max(4, n_combos // 4)]:
            restart.get_or_pack(c, with_runs=False)
        r = restart.stats()
        col.add("splice/warm_restart_splices", r["splices"], "splices",
                f"fresh cache, per-graph disk entries — packs="
                f"{r['packs']} graph_packs={r['graph_packs']} "
                f"graph_disk_hits={r['graph_disk_hits']}")

        if assert_splice:
            if fwd_x < 3.0:
                raise AssertionError(
                    f"splice gate: forward splice speedup {fwd_x:.2f}x "
                    f"< 3x over cold pack")
            if s["splices"] != n_combos or s["packs"] != 0:
                raise AssertionError(
                    f"splice gate: expected {n_combos} splices and zero "
                    f"packs, got splices={s['splices']} packs={s['packs']}")
            if r["splices"] < 1 or r["packs"] != 0 or r["graph_packs"] != 0:
                raise AssertionError(
                    f"splice gate: warm restart must splice from disk "
                    f"without packing, got {r}")
            from repro.pipeline import splice_schedules
            sample = combos[0]
            solos = [pack_batch([g], with_runs=False) for g in sample]
            got = splice_schedules(sample, solos)
            want = pack_batch(sample)
            for f in ("child_ids", "child_mask", "ext_ids", "node_mask",
                      "slot_of", "node_valid", "root_slots", "num_nodes",
                      "sort_perm", "sorted_child_ids", "run_head"):
                if not np.array_equal(getattr(got, f), getattr(want, f)):
                    raise AssertionError(
                        f"splice gate: field {f} differs from the "
                        f"monolithic pack")


def _skewed_corpus(n_samples: int, seed: int = 0):
    """A corpus with real-traffic skew: a few HOT topologies carry most
    of the mass, a long tail of rare shapes carries the rest, and
    arrival order is shuffled — the case where FIFO slicing almost
    never repeats a batch fingerprint but composition can."""
    rng = np.random.default_rng(seed)
    hot = [random_binary_tree(int(s), np.random.default_rng(100 + i))
           for i, s in enumerate((6, 6, 10, 14, 22))]
    corpus = []
    for _ in range(n_samples):
        if rng.random() < 0.75:           # hot mass, Zipf-ish within
            corpus.append(hot[min(int(rng.zipf(1.6)) - 1, len(hot) - 1)])
        else:                              # tail: fresh random shape
            corpus.append(random_binary_tree(int(rng.integers(2, 28)), rng))
    rng.shuffle(corpus)
    return corpus


def _epoch_through_pipeline(batches, pipe: SchedulePipeline):
    """Run a batch plan (``(graphs, pads)`` pairs; ``pads="policy"``
    for FIFO) through a pipeline; returns mean occupancy."""
    occ = []
    for graphs, pads in batches:
        inputs = [np.zeros((g.num_nodes, 1), np.float32) for g in graphs]
        pb = pipe.pack(graphs, inputs, pads=pads)
        occ.append(pb.sched.occupancy)
    return float(np.mean(occ))


def bench_composer(col: Collector, *, n_samples: int = 256, bs: int = 16,
                   assert_compose: bool = False,
                   persist_dir: str = None, assert_warm: bool = False):
    """``composer/*`` rows: FIFO vs composed batch formation on the
    skewed corpus — measured hit rate, occupancy, compile count — plus
    the optional persistent-store leg."""
    corpus = _skewed_corpus(n_samples)
    policy = BucketPolicy(mode="pow2")

    fifo_plan = [(corpus[i: i + bs], "policy")
                 for i in range(0, len(corpus), bs)]
    pipe_fifo = SchedulePipeline(1, bucket_policy=policy,
                                 cache=ScheduleCache(enabled=True,
                                                     persist=False))
    fifo_occ = _epoch_through_pipeline(fifo_plan, pipe_fifo)

    # Equal compile budget: the composer may use at most as many
    # distinct padded shapes as FIFO slicing produced — the hit-rate
    # and occupancy wins below are NOT bought with extra compiles.
    composer = BatchComposer(
        bs, bucket_policy=policy,
        shape_budget=pipe_fifo.stats()["compiled_shapes"])
    composed, cstats = composer.compose(corpus)
    pipe_comp = SchedulePipeline(
        1, bucket_policy=policy,
        cache=ScheduleCache(enabled=True,
                            persist=persist_dir if persist_dir else False))
    comp_occ = _epoch_through_pipeline([(b.graphs, b.pads)
                                        for b in composed], pipe_comp)

    f, c = pipe_fifo.stats(), pipe_comp.stats()
    col.add("composer/fifo_hit_rate", f["hit_rate"], "frac",
            f"{n_samples} samples bs={bs}, arrival order")
    col.add("composer/composed_hit_rate", c["hit_rate"], "frac",
            f"{cstats.num_groups} groups -> {cstats.group_batches} whole "
            f"+ {cstats.leftover_batches} leftover batches")
    col.add("composer/fifo_occupancy", fifo_occ, "frac",
            f"mean padded T*M slot occupancy, pow2 buckets")
    col.add("composer/composed_occupancy", comp_occ, "frac",
            f"greedy depth/size fill")
    col.add("composer/fifo_compile_count", f["compiled_shapes"],
            "programs", f"{len(fifo_plan)} batches")
    col.add("composer/composed_compile_count", c["compiled_shapes"],
            "programs", f"{cstats.num_batches} batches")
    col.add("composer/composed_packs", c["packs"], "packs",
            "pack_batch executions (disk tier may serve the rest)")
    if persist_dir:
        col.add("composer/persist_disk_hits", c["disk_hits"], "loads",
                f"store={persist_dir}")
    if assert_compose:
        if not (c["hit_rate"] > f["hit_rate"]):
            raise AssertionError(
                f"composer gate: composed hit rate {c['hit_rate']:.2f} "
                f"must beat FIFO {f['hit_rate']:.2f}")
        if not (comp_occ > fifo_occ):
            raise AssertionError(
                f"composer gate: composed occupancy {comp_occ:.2f} must "
                f"beat FIFO {fifo_occ:.2f}")
        if c["compiled_shapes"] > f["compiled_shapes"]:
            raise AssertionError(
                f"composer gate: composed compile count "
                f"{c['compiled_shapes']} worse than FIFO "
                f"{f['compiled_shapes']}")
    if assert_warm:
        if not persist_dir:
            raise AssertionError("--assert-warm requires --persist-dir")
        if c["packs"] != 0 or c["disk_hits"] < 1:
            raise AssertionError(
                f"warm-restart gate: expected zero pack_batch calls and "
                f">=1 disk hit, got packs={c['packs']} "
                f"disk_hits={c['disk_hits']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--assert-cache", action="store_true",
                    help="fail unless the second epoch over the same "
                         "corpus hits >=90%% in the schedule cache")
    ap.add_argument("--assert-compose", action="store_true",
                    help="fail unless composed batching beats FIFO on "
                         "hit rate and occupancy (compile count no worse)")
    ap.add_argument("--assert-splice", action="store_true",
                    help="fail unless the per-graph tier splices every "
                         "unseen combination >=3x faster than a cold "
                         "pack, byte-identically, and warm-restarts "
                         "without packing")
    ap.add_argument("--persist-dir", default=None,
                    help="route the composed leg through an on-disk "
                         "schedule store at this directory")
    ap.add_argument("--assert-warm", action="store_true",
                    help="with --persist-dir: fail unless the run is "
                         "served entirely from the store (zero packs)")
    ap.add_argument("--pipeline-only", action="store_true",
                    help="skip the Fig. 9 compute/retrace sweeps and run "
                         "only the host-side pipeline rows (the CI gate)")
    args = ap.parse_args(argv)
    col = Collector()
    if not args.pipeline_only:
        bench(col, leaves_list=(32, 64, 128, 256, 512, 1024) if args.full
              else (32, 128))
    bench_pipeline(col, **({"n_topologies": 48, "bs": 32} if args.full
                           else {}),
                   assert_cache=args.assert_cache)
    bench_splice(col, **({"n_topologies": 48, "bs": 32, "n_combos": 32}
                         if args.full else {}),
                 assert_splice=args.assert_splice)
    bench_composer(col, **({"n_samples": 512, "bs": 32} if args.full
                           else {}),
                   assert_compose=args.assert_compose,
                   persist_dir=args.persist_dir,
                   assert_warm=args.assert_warm)
    return col


if __name__ == "__main__":
    main()
