"""Paper Fig. 9: graph construction/preprocessing overhead vs
computation.

Cavs reads the input graph "through I/O": per minibatch the only
structure work is the host-side level packing (pure NumPy).  The
dynamic-declaration tax is re-TRACING the program per batch (Fold's
preprocessing / DyNet's per-sample graph build); we measure it as
jax re-trace + re-compile time of the same step.

Outputs both axes of Fig. 9: absolute seconds and the fraction of the
total step the structure work takes.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.scheduler import execute
from repro.core.structure import fit_bucket, pack_batch, pack_external


def bench(col: Collector, leaves_list, bs: int = 16, hidden: int = 32):
    m = get_paper_model("tree_fc")
    fn = m.make_vertex(hidden=hidden, input_dim=32)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for leaves in leaves_list:
        graphs = m.make_graphs(bs, leaves=leaves)
        inputs = [rng.standard_normal((g.num_nodes, 32)).astype(np.float32)
                  for g in graphs]

        # --- Cavs: host-side packing only -----------------------------
        t0 = time.perf_counter()
        sched = pack_batch(graphs, pad_arity=2)
        ext_np = pack_external(inputs, sched, 32)
        t_pack = time.perf_counter() - t0

        dev = sched.to_device()
        ext = jnp.asarray(ext_np)
        run = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
        t_compute = time_fn(lambda: run(params, ext))
        col.add("graphcons/cavs_pack", t_pack * 1e3, "ms",
                f"leaves={leaves} bs={bs}")
        col.add("graphcons/cavs_compute", t_compute * 1e3, "ms",
                f"leaves={leaves} bs={bs}")
        col.add("graphcons/cavs_overhead_frac",
                t_pack / (t_pack + t_compute), "frac",
                f"leaves={leaves} (paper: Fold wastes 0.5-0.8 here)")

        # --- dynamic declaration: re-trace per batch -------------------
        def redeclare():
            f = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
            return f(params, ext)

        t_total_re = time_fn(redeclare, warmup=0, iters=2)
        t_construct = max(t_total_re - t_compute, 0.0)
        col.add("graphcons/redeclare_construct", t_construct * 1e3, "ms",
                f"leaves={leaves} (trace+compile per batch)")
        col.add("graphcons/redeclare_overhead_frac",
                t_construct / max(t_total_re, 1e-12), "frac",
                f"leaves={leaves}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, leaves_list=(32, 64, 128, 256, 512, 1024))
    else:
        bench(col, leaves_list=(32, 128))
    return col


if __name__ == "__main__":
    main()
