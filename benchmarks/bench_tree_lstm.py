"""Paper Fig. 8(d)(h) + Table 1 (right): binary child-sum Tree-LSTM on
SST-like random parses (≤ 54 leaves), batch-size sweep, training step
(forward + parameter gradients) like the paper's epochs."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.scheduler import (execute, execute_lazy, execute_serial,
                                  readout_roots)
from repro.core.structure import fit_bucket, pack_batch, pack_external


def setup(bs: int, hidden: int, input_dim: int = 64, seed: int = 0):
    m = get_paper_model("tree_lstm")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    rng = np.random.default_rng(seed)
    graphs = m.make_graphs(bs, rng=rng)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=2)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched, graphs, inputs, ext


def bench(col: Collector, bs_list, h_list):
    for bs in bs_list:
        for h in h_list:
            fn, params, sched, graphs, inputs, ext = setup(bs, h)
            dev = sched.to_device()

            def train_step(p, e):
                def loss(pp, ee):
                    buf = execute_lazy(fn, pp, ee, dev)
                    return jnp.sum(readout_roots(buf, dev) ** 2)
                return jax.grad(loss)(p, e)

            step = jax.jit(train_step)
            t_b = time_fn(lambda: step(params, ext))
            col.add("tree_lstm/train_batched", t_b * 1e3, "ms",
                    f"bs={bs} h={h} occ={sched.occupancy:.2f}")

            fwd = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
            t_f = time_fn(lambda: fwd(params, ext))
            col.add("tree_lstm/fwd_batched", t_f * 1e3, "ms",
                    f"bs={bs} h={h}")

            t_s = time_fn(
                lambda: execute_serial(fn, params, graphs[:2], inputs[:2]),
                warmup=1, iters=2) * (bs / 2)
            col.add("tree_lstm/fwd_serial", t_s * 1e3, "ms",
                    f"bs={bs} h={h} (extrapolated)")
            col.add("tree_lstm/fwd_speedup", t_s / t_f, "x",
                    f"bs={bs} h={h}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(16, 64, 256), h_list=(64, 256, 512))
    else:
        bench(col, bs_list=(16,), h_list=(64,))
    return col


if __name__ == "__main__":
    main()
