"""Paper Fig. 8(d)(h) + Table 1 (right): binary child-sum Tree-LSTM on
SST-like random parses (≤ 54 leaves), batch-size sweep, training step
(forward + parameter gradients) like the paper's epochs."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn, time_stats
from repro.configs.paper import get_paper_model
from repro.core.scheduler import (execute, execute_lazy, execute_serial,
                                  readout_roots)
from repro.core.structure import fit_bucket, pack_batch, pack_external


def setup(bs: int, hidden: int, input_dim: int = 64, seed: int = 0):
    m = get_paper_model("tree_lstm")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    rng = np.random.default_rng(seed)
    graphs = m.make_graphs(bs, rng=rng)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=2)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched, graphs, inputs, ext


def bench(col: Collector, bs_list, h_list):
    for bs in bs_list:
        for h in h_list:
            fn, params, sched, graphs, inputs, ext = setup(bs, h)
            dev = sched.to_device()

            def train_step(mode):
                def step(p, e):
                    def loss(pp, ee):
                        buf = execute_lazy(fn, pp, ee, dev, fusion_mode=mode)
                        return jnp.sum(readout_roots(buf, dev) ** 2)
                    return jax.grad(loss)(p, e)
                return jax.jit(step)

            det = f"bs={bs} h={h} occ={sched.occupancy:.2f}"
            step_un = train_step("none")
            st_un = time_stats(lambda: step_un(params, ext))
            col.add_time("tree_lstm/train_batched", st_un, det)
            step_fu = train_step("megastep")
            st_fu = time_stats(lambda: step_fu(params, ext))
            col.add_time("tree_lstm/train_megastep", st_fu, det)
            col.add("tree_lstm/train_megastep_speedup",
                    st_un["p50_ms"] / st_fu["p50_ms"], "x", det)

            fwd = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                               fusion_mode="none").buf)
            sf_un = time_stats(lambda: fwd(params, ext))
            t_f = sf_un["p50_ms"] / 1e3
            col.add_time("tree_lstm/fwd_batched", sf_un, f"bs={bs} h={h}")
            fwd_fu = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                                  fusion_mode="megastep").buf)
            sf_fu = time_stats(lambda: fwd_fu(params, ext))
            col.add_time("tree_lstm/fwd_megastep", sf_fu, f"bs={bs} h={h}")
            col.add("tree_lstm/fwd_megastep_speedup",
                    sf_un["p50_ms"] / sf_fu["p50_ms"], "x", f"bs={bs} h={h}")

            t_s = time_fn(
                lambda: execute_serial(fn, params, graphs[:2], inputs[:2]),
                warmup=1, iters=2) * (bs / 2)
            col.add("tree_lstm/fwd_serial", t_s * 1e3, "ms",
                    f"bs={bs} h={h} (extrapolated)")
            col.add("tree_lstm/fwd_speedup", t_s / t_f, "x",
                    f"bs={bs} h={h}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(16, 64, 256), h_list=(64, 256, 512))
    else:
        bench(col, bs_list=(16,), h_list=(64,))
    return col


if __name__ == "__main__":
    main()
