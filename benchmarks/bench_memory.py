"""Paper Table 2: memory-operation vs computation breakdown.

Cavs' claim: gather/scatter movement happens only at the entrance/exit
of F (one batched take / one batched update per task), so its share is
small and shrinks with batch size.  We time:

  - the full batched step,
  - a 'memory ops only' variant (the same schedule executing ONLY the
    gather + scatter data movement with the cell math stubbed out),

and report both plus the dynamic-tensor buffer plan (bytes) from
``core.memory`` — the quantity Table 2 tracks.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.memory import plan_schedule
from repro.core.scheduler import execute
from repro.core.structure import pack_batch, pack_external
from repro.core.vertex import VertexIO, VertexOutput


def bench(col: Collector, bs_list, hidden: int = 64):
    m = get_paper_model("tree_lstm")
    rng = np.random.default_rng(0)
    for bs in bs_list:
        fn = m.make_vertex(hidden=hidden, input_dim=64)
        graphs = m.make_graphs(bs, rng=rng)
        params = fn.init(jax.random.PRNGKey(0))
        sched = pack_batch(graphs, pad_arity=2)
        inputs = [rng.standard_normal((g.num_nodes, 64)).astype(np.float32)
                  for g in graphs]
        ext = jnp.asarray(pack_external(inputs, sched, 64))
        dev = sched.to_device()

        run = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
        t_full = time_fn(lambda: run(params, ext))

        # memory-ops-only twin: gather + a trivial combine + scatter
        @dataclasses.dataclass(frozen=True)
        class MoveOnly:
            state_dim: int = fn.state_dim
            ext_dim: int = fn.ext_dim
            arity: int = 2

            def init(self, rng):
                return {}

            def apply(self, p, io: VertexIO) -> VertexOutput:
                s = io.gather_sum()            # the gather movement
                return VertexOutput(state=s)   # scatter movement

        mv = MoveOnly()
        run_mv = jax.jit(lambda e: execute(mv, {}, dev, e).buf)
        ext_s = jnp.zeros((sched.num_ext_rows + 1, mv.ext_dim), jnp.float32)
        t_mem = time_fn(lambda: run_mv(ext_s))

        col.add("memory/full_step", t_full * 1e3, "ms", f"bs={bs}")
        col.add("memory/memory_ops", t_mem * 1e3, "ms",
                f"bs={bs} (gather+scatter schedule only)")
        col.add("memory/mem_frac", t_mem / t_full, "frac",
                f"bs={bs} (paper Table 2: shrinks with bs)")

        plan = plan_schedule(sched, fn.state_dim, fn.ext_dim)
        r = plan.report()
        col.add("memory/buffer_bytes", r["total_bytes"], "bytes",
                f"bs={bs} occupancy={r['occupancy']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(16, 32, 64, 128, 256))
    else:
        bench(col, bs_list=(16, 64))
    return col


if __name__ == "__main__":
    main()
