"""Shared benchmark utilities: timing, CSV emission, model setup.

All benchmarks print ``name,value,unit,detail`` CSV rows so
``benchmarks/run.py`` can aggregate them into bench_output.txt, and
keep structured records (value + optional mean/p50 stats) that run.py
serializes to per-suite ``results/BENCH_<suite>.json`` files — the
machine-readable perf trajectory.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np


def _time_loop(fn: Callable[[], Any], warmup: int, iters: int,
               min_time_s: float) -> List[float]:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    t_total = 0.0
    i = 0
    while i < iters or t_total < min_time_s:
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        i += 1
        if i > 100:
            break
    return times


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5,
            min_time_s: float = 0.0) -> float:
    """Median wall seconds per call of a (jitted) thunk."""
    return float(np.median(_time_loop(fn, warmup, iters, min_time_s)))


def time_stats(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5,
               min_time_s: float = 0.0) -> Dict[str, float]:
    """Timing distribution of a thunk: ``p50_ms``, ``mean_ms``, ``iters``."""
    times = np.asarray(_time_loop(fn, warmup, iters, min_time_s))
    return {"p50_ms": float(np.median(times) * 1e3),
            "mean_ms": float(np.mean(times) * 1e3),
            "iters": int(times.size)}


def row(name: str, value: float, unit: str, detail: str = "") -> str:
    line = f"{name},{value:.6g},{unit},{detail}"
    print(line)
    return line


class Collector:
    """Accumulates benchmark rows both as printed CSV (legacy
    bench_output.txt path) and as structured records for BENCH_*.json."""

    def __init__(self):
        self.rows: List[str] = []
        self.records: List[Dict[str, Any]] = []

    def add(self, name: str, value: float, unit: str, detail: str = "",
            stats: Optional[Dict[str, float]] = None):
        self.rows.append(row(name, value, unit, detail))
        rec: Dict[str, Any] = {"name": name, "value": float(value),
                               "unit": unit, "detail": detail}
        if stats:
            rec.update(stats)
        self.records.append(rec)

    def add_time(self, name: str, stats: Dict[str, float], detail: str = ""):
        """Record a timing with its distribution (value = p50 ms)."""
        self.add(name, stats["p50_ms"], "ms", detail, stats=stats)
