"""Shared benchmark utilities: timing, CSV emission, model setup.

All benchmarks print ``name,value,unit,detail`` CSV rows so
``benchmarks/run.py`` can aggregate them into bench_output.txt.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5,
            min_time_s: float = 0.0) -> float:
    """Median wall seconds per call of a (jitted) thunk."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    t_total = 0.0
    i = 0
    while i < iters or t_total < min_time_s:
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        i += 1
        if i > 100:
            break
    return float(np.median(times))


def row(name: str, value: float, unit: str, detail: str = "") -> str:
    line = f"{name},{value:.6g},{unit},{detail}"
    print(line)
    return line


class Collector:
    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, value: float, unit: str, detail: str = ""):
        self.rows.append(row(name, value, unit, detail))
