"""Shared benchmark utilities: timing, CSV emission, model setup.

All benchmarks print ``name,value,unit,detail`` CSV rows so
``benchmarks/run.py`` can aggregate them into bench_output.txt, and
keep structured records (value + optional mean/p50 stats) that run.py
serializes to per-suite ``results/BENCH_<suite>.json`` files — the
machine-readable perf trajectory.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np


def _time_loop(fn: Callable[[], Any], warmup: int, iters: int,
               min_time_s: float) -> List[float]:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    t_total = 0.0
    i = 0
    while i < iters or t_total < min_time_s:
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        times.append(dt)
        t_total += dt
        i += 1
        if i > 100:
            break
    return times


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5,
            min_time_s: float = 0.0) -> float:
    """Median wall seconds per call of a (jitted) thunk."""
    return float(np.median(_time_loop(fn, warmup, iters, min_time_s)))


def time_stats(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 5,
               min_time_s: float = 0.0) -> Dict[str, float]:
    """Timing distribution of a thunk: ``p50_ms``, ``mean_ms``, ``iters``."""
    times = np.asarray(_time_loop(fn, warmup, iters, min_time_s))
    return {"p50_ms": float(np.median(times) * 1e3),
            "mean_ms": float(np.mean(times) * 1e3),
            "iters": int(times.size)}


def row(name: str, value: float, unit: str, detail: str = "") -> str:
    line = f"{name},{value:.6g},{unit},{detail}"
    print(line)
    return line


class Collector:
    """Accumulates benchmark rows both as printed CSV (legacy
    bench_output.txt path) and as structured records for BENCH_*.json."""

    def __init__(self):
        self.rows: List[str] = []
        self.records: List[Dict[str, Any]] = []

    def add(self, name: str, value: float, unit: str, detail: str = "",
            stats: Optional[Dict[str, float]] = None):
        self.rows.append(row(name, value, unit, detail))
        rec: Dict[str, Any] = {"name": name, "value": float(value),
                               "unit": unit, "detail": detail}
        if stats:
            rec.update(stats)
        self.records.append(rec)

    def add_time(self, name: str, stats: Dict[str, float], detail: str = ""):
        """Record a timing with its distribution (value = p50 ms)."""
        self.add(name, stats["p50_ms"], "ms", detail, stats=stats)


# ---------------------------------------------------------------------------
# Per-stage breakdown rows (obs.trace + obs.registry)
# ---------------------------------------------------------------------------

def emit_pipeline_stages(*, n_graphs: int = 12, batch_size: int = 4,
                         hidden: int = 32, input_dim: int = 32,
                         max_len: int = 12, seed: int = 0) -> None:
    """Drive one tiny compose → pack → fused fwd → fused bwd pass
    through :class:`~repro.pipeline.SchedulePipeline` so every pipeline
    stage span lands in the active registry's ``span.*`` histograms.

    No-op when no tracer is installed — suites stay zero-overhead when
    run standalone; ``benchmarks/run.py`` installs a per-suite tracer
    and calls this once per suite, so every ``BENCH_*.json`` carries
    the same stage-breakdown rows regardless of which paths the suite
    itself exercises.  The ``fwd``/``bwd`` spans time execution (the
    programs are compiled outside the spans, and the spans block on the
    result via ``maybe_block``)."""
    from repro.obs import trace
    if trace.get_tracer() is None:
        return
    import jax.numpy as jnp

    from repro.configs.paper import get_paper_model
    from repro.core.scheduler import execute, readout_roots
    from repro.pipeline import SchedulePipeline

    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    graphs = m.make_graphs(n_graphs, max_len=max_len, rng=rng)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)
                                  ).astype(np.float32) for g in graphs]
    pipe = SchedulePipeline(ext_dim=input_dim)
    batches, _ = pipe.compose(graphs, inputs, batch_size=batch_size)
    for i, cb in enumerate(batches[:2]):
        pb = pipe.pack(*cb.as_item())
        dev, ext = pb.dev, pb.ext

        def _loss(p, e, dev=dev):
            r = execute(fn, p, dev, e, fusion_mode="megastep")
            return jnp.sum(readout_roots(r.buf, dev) ** 2)

        fwd = jax.jit(lambda p, e, dev=dev: execute(
            fn, p, dev, e, fusion_mode="megastep").buf)
        bwd = jax.jit(jax.grad(_loss))
        jax.block_until_ready(fwd(params, ext))   # compile outside spans
        jax.block_until_ready(bwd(params, ext))
        with trace.correlate(batch=i):
            with trace.span("fwd", batch=i):
                trace.maybe_block(fwd(params, ext))
            with trace.span("bwd", batch=i):
                trace.maybe_block(bwd(params, ext))


def add_stage_rows(col: Collector, registry=None) -> int:
    """Turn the active registry's ``span.*`` histograms into
    ``stage/<name>`` records (value = p50 ms, with mean/iters stats) so
    ``compare.py`` diffs the per-stage breakdown alongside the suite's
    own rows.  Returns the number of rows added."""
    from repro.obs.registry import get_registry
    reg = registry if registry is not None else get_registry()
    snap = reg.snapshot()
    added = 0
    for key in sorted(snap["histograms"]):
        if not key.startswith("span."):
            continue
        s = snap["histograms"][key]
        col.add_time(f"stage/{key[len('span.'):]}",
                     {"p50_ms": s["p50"], "mean_ms": s["mean"],
                      "iters": s["count"]},
                     detail=f"window={s['window']}")
        added += 1
    return added
