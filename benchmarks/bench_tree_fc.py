"""Paper Fig. 8(c)(g) + Table 1 (left): Tree-FC over complete binary
trees (the Fold loom benchmark; 256 leaves → 511 vertices)."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn, time_stats
from repro.configs.paper import get_paper_model
from repro.core.scheduler import execute, execute_serial
from repro.core.structure import pack_batch, pack_external


def setup(bs: int, hidden: int, leaves: int, input_dim: int = 64):
    m = get_paper_model("tree_fc")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    graphs = m.make_graphs(bs, leaves=leaves)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs, pad_arity=fn.arity)
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched, graphs, inputs, ext


def bench(col: Collector, bs_list, leaves_list, hidden: int = 64):
    for bs in bs_list:
        for leaves in leaves_list:
            fn, params, sched, graphs, inputs, ext = setup(bs, hidden, leaves)
            dev = sched.to_device()
            det = f"bs={bs} leaves={leaves} h={hidden} T={sched.T} M={sched.M}"
            run = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                               fusion_mode="none").buf)
            st_un = time_stats(lambda: run(params, ext))
            t_b = st_un["p50_ms"] / 1e3
            col.add_time("tree_fc/batched", st_un, det)
            run_fu = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                                  fusion_mode="megastep").buf)
            st_fu = time_stats(lambda: run_fu(params, ext))
            col.add_time("tree_fc/megastep", st_fu, det)
            col.add("tree_fc/megastep_speedup",
                    st_un["p50_ms"] / st_fu["p50_ms"], "x",
                    f"bs={bs} leaves={leaves} (fused treefc megastep vs "
                    f"op-by-op; CPU wall-clock advisory)")
            t_s = time_fn(
                lambda: execute_serial(fn, params, graphs[:1], inputs[:1]),
                warmup=1, iters=2) * bs
            col.add("tree_fc/serial", t_s * 1e3, "ms",
                    f"bs={bs} leaves={leaves} (extrapolated)")
            col.add("tree_fc/speedup", t_s / t_b, "x",
                    f"bs={bs} leaves={leaves}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(16, 64), leaves_list=(32, 128, 256, 512),
              hidden=128)
    else:
        bench(col, bs_list=(8,), leaves_list=(32, 128), hidden=32)
    return col


if __name__ == "__main__":
    main()
