"""Paper Fig. 8(a)(e): Fixed-LSTM LM, 64 steps.

Three execution policies over identical math:

  - ``batched``   — the Cavs batching policy (one compiled program,
                    level-sync batched execution);
  - ``serial``    — per-vertex per-sample execution (the dynamic-
                    declaration / DyNet stand-in; no cross-sample
                    batching);
  - ``redeclare`` — batched math but re-traced EVERY batch (the
                    per-sample graph-construction overhead axis of
                    Fold/DyNet; §5.2).

The paper's claim reproduced: batched ≫ serial, with the gap growing in
``bs`` (paper: 1.7x → 36x from bs 2 → 128); and redeclaration overhead
is a constant tax per batch that batching alone does not remove.
"""

from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn
from repro.configs.paper import get_paper_model
from repro.core.scheduler import execute, execute_serial
from repro.core.structure import pack_batch, pack_external


def setup(bs: int, hidden: int, steps: int = 64, input_dim: int = 64):
    m = get_paper_model("fixed_lstm")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    graphs = m.make_graphs(bs, steps=steps)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs)
    rng = np.random.default_rng(0)
    inputs = [rng.standard_normal((steps, input_dim)).astype(np.float32)
              for _ in range(bs)]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched, graphs, inputs, ext


def bench(col: Collector, bs_list, h_list, steps: int = 64):
    for bs in bs_list:
        for h in h_list:
            fn, params, sched, graphs, inputs, ext = setup(bs, h, steps)
            dev = sched.to_device()
            run = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
            t_b = time_fn(lambda: run(params, ext))
            col.add("fixed_lstm/batched", t_b * 1e3, "ms",
                    f"bs={bs} h={h} steps={steps}")
            # serial = dynamic-declaration stand-in (one sample to keep
            # CPU wall time sane; per-epoch cost scales by bs)
            t_s = time_fn(
                lambda: execute_serial(fn, params, graphs[:1], inputs[:1]),
                warmup=1, iters=2) * bs
            col.add("fixed_lstm/serial", t_s * 1e3, "ms",
                    f"bs={bs} h={h} (extrapolated from 1 sample)")
            col.add("fixed_lstm/speedup", t_s / t_b, "x",
                    f"bs={bs} h={h}")
            # redeclare: re-trace each call (Fold-ish construction tax)
            def redeclared():
                f = jax.jit(lambda p, e: execute(fn, p, dev, e).buf)
                return f(params, ext)
            t_r = time_fn(redeclared, warmup=1, iters=2)
            col.add("fixed_lstm/redeclare", t_r * 1e3, "ms",
                    f"bs={bs} h={h} retrace-every-batch")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(1, 8, 32, 128), h_list=(64, 256, 512))
    else:
        bench(col, bs_list=(1, 16), h_list=(64,), steps=32)
    return col


if __name__ == "__main__":
    main()
