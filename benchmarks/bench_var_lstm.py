"""Paper Fig. 8(b)(f): Var-LSTM LM over variable-length sequences.

Adds the policy the paper attributes to static-declaration TF:
``pad_to_max`` — pad every sequence in the batch to the longest and
run a dense scan (wasted compute on padding).  Cavs' level packing
only schedules real vertices (occupancy < 1 shows as smaller M per
level, not wasted FLOPs per slot... the padded slots DO cost compute;
the packer reports occupancy so the waste is measured, and bucketing
keeps one compiled program).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_fn, time_stats
from repro.configs.paper import get_paper_model
from repro.core.scheduler import execute, execute_serial, readout_roots
from repro.core.structure import chain, pack_batch, pack_external
from repro.kernels.level_megastep import (level_bwd_traffic_bytes,
                                          level_traffic_bytes)
from repro.serve import VertexRequest, VertexServeEngine


def setup(bs: int, hidden: int, max_len: int = 64, input_dim: int = 64,
          seed: int = 0):
    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=hidden, input_dim=input_dim)
    rng = np.random.default_rng(seed)
    graphs = m.make_graphs(bs, max_len=max_len, rng=rng)
    params = fn.init(jax.random.PRNGKey(0))
    sched = pack_batch(graphs)
    inputs = [rng.standard_normal((g.num_nodes, input_dim)).astype(np.float32)
              for g in graphs]
    ext = jnp.asarray(pack_external(inputs, sched, input_dim))
    return fn, params, sched, graphs, inputs, ext


def bench(col: Collector, bs_list, h_list, max_len: int = 64):
    for bs in bs_list:
        for h in h_list:
            fn, params, sched, graphs, inputs, ext = setup(bs, h, max_len)
            dev = sched.to_device()
            run = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                               fusion_mode="none").buf)
            det = f"bs={bs} h={h} occupancy={sched.occupancy:.2f}"
            sb_un = time_stats(lambda: run(params, ext))
            t_b = sb_un["p50_ms"] / 1e3
            col.add_time("var_lstm/batched", sb_un, det)
            run_fu = jax.jit(lambda p, e: execute(fn, p, dev, e,
                                                  fusion_mode="megastep").buf)
            sb_fu = time_stats(lambda: run_fu(params, ext))
            col.add_time("var_lstm/megastep", sb_fu, det)
            col.add("var_lstm/megastep_speedup",
                    sb_un["p50_ms"] / sb_fu["p50_ms"], "x",
                    f"bs={bs} h={h} (fused level-megastep vs op-by-op)")

            # Train direction: fused fwd + fused bwd sweep (one
            # bwd_megastep per reverse level) vs grad-through-scan.
            def _loss(p, e, mode):
                r = execute(fn, p, dev, e, fusion_mode=mode)
                return jnp.sum(readout_roots(r.buf, dev) ** 2)

            g_un = jax.jit(jax.grad(lambda p, e: _loss(p, e, "none")))
            g_fu = jax.jit(jax.grad(lambda p, e: _loss(p, e, "megastep")))
            sg_un = time_stats(lambda: g_un(params, ext))
            sg_fu = time_stats(lambda: g_fu(params, ext))
            col.add_time("var_lstm/train_unfused", sg_un, det)
            col.add_time("var_lstm/train_megastep", sg_fu, det)
            col.add("var_lstm/train_megastep_speedup",
                    sg_un["p50_ms"] / sg_fu["p50_ms"], "x",
                    f"bs={bs} h={h} (fused fwd + fused bwd sweep; CPU "
                    f"wall-clock advisory)")
            S = fn.state_dim
            gb_un = level_bwd_traffic_bytes("lstm", dev.M, dev.A, S, h,
                                            fused=False)
            gb_fu = level_bwd_traffic_bytes("lstm", dev.M, dev.A, S, h,
                                            fused=True)
            col.add("var_lstm/bwd_hbm_bytes_per_level_unfused", gb_un, "B",
                    f"bs={bs} h={h} M={dev.M}")
            col.add("var_lstm/bwd_hbm_bytes_per_level_megastep", gb_fu, "B",
                    f"bs={bs} h={h} M={dev.M}")
            col.add("var_lstm/bwd_hbm_reduction", gb_un / gb_fu, "x",
                    f"bs={bs} h={h} (modeled reverse-level round-trips)")

            # pad-to-max static unrolling (the TF baseline of §2.2)
            padded = [chain(max_len) for _ in range(bs)]
            sched_p = pack_batch(padded)
            inputs_p = [np.zeros((max_len, fn.input_dim), np.float32)
                        for _ in range(bs)]
            for i, x in enumerate(inputs):
                inputs_p[i][: x.shape[0]] = x
            ext_p = jnp.asarray(pack_external(inputs_p, sched_p,
                                              fn.input_dim))
            dev_p = sched_p.to_device()
            run_p = jax.jit(lambda p, e: execute(fn, p, dev_p, e,
                                                 fusion_mode="none").buf)
            t_p = time_fn(lambda: run_p(params, ext_p))
            col.add("var_lstm/pad_to_max", t_p * 1e3, "ms",
                    f"bs={bs} h={h}")
            col.add("var_lstm/pack_vs_pad", t_p / t_b, "x",
                    f"bs={bs} h={h} (Cavs packing vs static unroll)")

            t_s = time_fn(
                lambda: execute_serial(fn, params, graphs[:1], inputs[:1]),
                warmup=1, iters=2) * bs
            col.add("var_lstm/serial", t_s * 1e3, "ms",
                    f"bs={bs} h={h} (extrapolated)")


def bench_decode(col: Collector, slots: int, h: int, input_dim: int = 64):
    """Serving decode path (VertexServeEngine): one tick = one batching
    task over the slot pool, fused vs op-by-op, at steady state (every
    slot live the whole measurement — requests far longer than the
    timed window)."""
    m = get_paper_model("var_lstm")
    fn = m.make_vertex(hidden=h, input_dim=input_dim)
    params = fn.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    det = f"slots={slots} h={h}"

    stats = {}
    for mode in ("none", "megastep"):
        eng = VertexServeEngine(fn, params, num_slots=slots,
                                fusion_mode=mode)
        for i in range(slots):
            eng.submit(VertexRequest(
                request_id=i,
                inputs=rng.standard_normal((2048, input_dim)
                                           ).astype(np.float32)))
        eng.step()                      # admit + compile the tick
        # Return the device buffer so time_stats' block_until_ready
        # actually waits for the tick's computation (async dispatch).
        stats[mode] = time_stats(lambda: (eng.step(), eng._buf)[1],
                                 warmup=3, iters=20)
        col.add_time(f"var_lstm/decode_tick_{'megastep' if eng.fused else 'unfused'}",
                     stats[mode], det)
    col.add("var_lstm/decode_megastep_speedup",
            stats["none"]["p50_ms"] / stats["megastep"]["p50_ms"], "x",
            f"{det} (fused decode tick vs op-by-op; CPU wall-clock advisory)")

    # Structural accelerator evidence for the decode tick (M = slot
    # pool, A = 1 chain gather): launches and modeled HBM bytes.
    S = fn.state_dim
    b_un = level_traffic_bytes("lstm", slots, 1, S, h, fused=False)
    b_fu = level_traffic_bytes("lstm", slots, 1, S, h, fused=True)
    col.add("var_lstm/decode_launches_per_level_unfused", 3, "kernels",
            f"{det} gather + cell + scatter as separate XLA ops")
    col.add("var_lstm/decode_launches_per_level_megastep", 1, "kernels",
            f"{det} structural: one pallas_call per tick")
    col.add("var_lstm/decode_hbm_bytes_per_level_unfused", b_un, "B", det)
    col.add("var_lstm/decode_hbm_bytes_per_level_megastep", b_fu, "B", det)
    col.add("var_lstm/decode_hbm_reduction", b_un / b_fu, "x",
            f"{det} modeled HBM round-trips per decode tick")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    col = Collector()
    if args.full:
        bench(col, bs_list=(8, 32, 128), h_list=(64, 256, 512))
        bench_decode(col, slots=64, h=256)
    else:
        bench(col, bs_list=(16,), h_list=(64,), max_len=32)
        bench_decode(col, slots=8, h=64)
    return col


if __name__ == "__main__":
    main()
