"""Distributed data parallel (beyond-paper): sharded composition
quality and the mesh train step.

Host-side (any device count): ``BatchComposer.compose_sharded`` is
scored on the two properties the trainer depends on — replica NODE
BALANCE (no replica stalls the all-reduce behind a heavier schedule)
and PER-REPLICA schedule-cache hit rate in a warm epoch (every
replica's fingerprint stream must stay stable, or the data-parallel
speedup drowns in re-packing).  Both are CI-gated via
``--assert-balance`` / ``--assert-hits`` in the tier1-dist bench-smoke
step.

Mesh-side (needs ≥2 host devices, e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``): wall time of
the ``dp_shard`` megastep train step — stacked ``DeviceSchedule``,
``shard_map`` over the data axis, int8+EF gradient reduction.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Collector, time_stats
from repro.core.scheduler import execute, readout_roots
from repro.core.structure import random_binary_tree
from repro.dist.elastic import remesh
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import ShardedPipeline
from repro.train import TrainConfig, Trainer

INPUT_DIM, HIDDEN = 8, 4


def _corpus(seed, n, max_nodes):
    rng = np.random.default_rng(seed)
    graphs = [random_binary_tree(int(rng.integers(2, max_nodes)), rng)
              for _ in range(n)]
    inputs = [rng.standard_normal((g.num_nodes, INPUT_DIM))
              .astype(np.float32) * 0.3 for g in graphs]
    return graphs, inputs


def _host_side(col, args, graphs, inputs, shards, batch_size):
    pipe = ShardedPipeline(INPUT_DIM, shards)
    comp = pipe.composer(batch_size)

    t0 = time.perf_counter()
    steps, stats = comp.compose_sharded(graphs, inputs,
                                        num_shards=shards)
    compose_ms = (time.perf_counter() - t0) * 1e3
    col.add("compose_sharded", compose_ms, "ms",
            f"n={len(graphs)} shards={shards} steps={stats.num_steps}")
    col.add("replica_node_imbalance", stats.node_imbalance, "ratio",
            f"max/min of {list(stats.replica_nodes)}")
    col.add("fillers", stats.num_fillers, "samples",
            f"of {len(graphs)} real")

    # epoch 1 (cold) then epoch 2 (warm) through per-replica caches
    t0 = time.perf_counter()
    for st in steps:
        pipe.pack_step(st)
    cold_ms = (time.perf_counter() - t0) * 1e3
    snaps = [dict(p.cache.stats()) for p in pipe.pipes]
    steps2, _ = comp.compose_sharded(graphs, inputs, num_shards=shards)
    t0 = time.perf_counter()
    for st in steps2:
        pipe.pack_step(st)
    warm_ms = (time.perf_counter() - t0) * 1e3
    col.add("epoch_pack_cold", cold_ms, "ms", f"{len(steps)} steps")
    col.add("epoch_pack_warm", warm_ms, "ms", f"{len(steps)} steps")

    hit_rates = []
    for r, p in enumerate(pipe.pipes):
        s = p.cache.stats()
        hits = s["hits"] - snaps[r]["hits"]
        total = hits + (s["misses"] - snaps[r]["misses"])
        hit_rates.append(hits / total if total else 0.0)
    col.add("epoch2_hit_rate_min", min(hit_rates), "rate",
            f"per-replica {['%.2f' % h for h in hit_rates]}")

    if args.assert_balance is not None \
            and stats.node_imbalance > args.assert_balance:
        print(f"# GATE FAILED: node imbalance {stats.node_imbalance:.3f}"
              f" > {args.assert_balance}", flush=True)
        sys.exit(1)
    if args.assert_hits is not None \
            and min(hit_rates) < args.assert_hits:
        print(f"# GATE FAILED: min per-replica epoch-2 hit rate "
              f"{min(hit_rates):.3f} < {args.assert_hits}", flush=True)
        sys.exit(1)
    return steps, pipe


def _mesh_side(col, graphs, inputs, batch_size):
    n_dev = len(jax.devices())
    if n_dev < 2:
        col.add("sharded_train_step", 0.0, "ms",
                "skipped: single device (set XLA_FLAGS="
                "--xla_force_host_platform_device_count=8)")
        return
    shards = n_dev
    fn = TreeLSTMVertex(input_dim=INPUT_DIM, hidden=HIDDEN, arity=2)
    mesh = remesh(jax.devices(), {"data": shards})

    def loss_fn(params, batch):
        buf = execute(fn, params, batch["dev"], batch["ext"],
                      fusion_mode="auto").buf
        root_h = readout_roots(buf, batch["dev"])[:, HIDDEN:]
        per = jnp.mean(root_h ** 2, axis=-1)
        return jnp.sum(per * batch["weights"]), {}

    pipe = ShardedPipeline(INPUT_DIM, shards)
    tr = Trainer(loss_fn, lambda k: fn.init(k),
                 TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10 ** 6,
                             weight_decay=0.0, log_every=10 ** 6,
                             dp_shard=True, compress_grads=True),
                 mesh=mesh)
    state = tr.init_state(jax.random.PRNGKey(0))
    steps, _ = pipe.composer(batch_size).compose_sharded(
        graphs, inputs, num_shards=shards)
    batch = pipe.pack_step(steps[0])
    with mesh:
        step_fn = tr._build_step(batch)
        state, _ = step_fn(state, batch)        # compile + warm

        def once():
            nonlocal state
            state, m = step_fn(state, batch)
            return m["loss"]

        col.add_time("sharded_train_step", time_stats(once, iters=10),
                     f"R={shards} bs={batch_size} compress+EF")


def main(argv=None) -> Collector:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--assert-balance", type=float, default=None,
                    help="fail if replica node imbalance exceeds this")
    ap.add_argument("--assert-hits", type=float, default=None,
                    help="fail if any replica's epoch-2 cache hit rate "
                         "is below this")
    args = ap.parse_args(argv)

    col = Collector()
    if args.full:
        n, max_nodes, shards, bs = 1024, 48, 8, 64
    else:
        n, max_nodes, shards, bs = 256, 32, 8, 32
    graphs, inputs = _corpus(args.seed, n, max_nodes)
    _host_side(col, args, graphs, inputs, shards, bs)
    _mesh_side(col, graphs, inputs, bs)
    return col


if __name__ == "__main__":
    main()
