"""Mesh training: shard the megastep training path across devices.

Trains the quickstart Tree-LSTM data-parallel over a {"data": R} mesh:
``compose_sharded`` splits each composed batch into node-balanced
per-replica sub-batches, ``ShardedPipeline`` packs one LevelSchedule
per replica, and ``Trainer(dp_shard=True)`` runs the megastep under
``shard_map`` with int8 + error-feedback gradient all-reduce.

Run (8 fake host devices on a single CPU):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/train_mesh.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute, readout_roots
from repro.core.structure import random_binary_tree
from repro.dist.elastic import plan_downsize, remesh
from repro.models.treelstm import TreeLSTMVertex
from repro.pipeline import ShardedPipeline
from repro.train import MetricLogger, TrainConfig, Trainer

IN_DIM, HIDDEN = 16, 8

# --- 1. a mesh over whatever devices exist (forced-host CPUs count) ------
R = len(jax.devices())
mesh = remesh(jax.devices(), {"data": R})
print(f"mesh: {R} devices on axis 'data'")

# --- 2. vertex function + a ragged tree corpus ---------------------------
fn = TreeLSTMVertex(input_dim=IN_DIM, hidden=HIDDEN, arity=2)
rng = np.random.default_rng(0)
graphs = [random_binary_tree(int(rng.integers(2, 24)), rng)
          for _ in range(128)]
inputs = [rng.standard_normal((g.num_nodes, IN_DIM)).astype(np.float32)
          * 0.3 for g in graphs]
targets = rng.standard_normal((128, HIDDEN)).astype(np.float32) * 0.1


# --- 3. the dp_shard loss contract: weighted SUM, not mean ---------------
# Each replica returns sum(per_sample * weights); the trainer psums the
# sums and weights across the mesh, so zero-weight filler samples (short
# final batches) drop out exactly and the global loss matches the
# single-replica baseline to fp roundoff.
def loss_fn(params, batch):
    buf = execute(fn, params, batch["dev"], batch["ext"],
                  fusion_mode="auto").buf
    root_h = readout_roots(buf, batch["dev"])[:, HIDDEN:]
    per = jnp.mean((root_h - batch["target"]) ** 2, axis=-1)
    return jnp.sum(per * batch["weights"]), {}


# --- 4. shard-aware pipeline + trainer -----------------------------------
pipe = ShardedPipeline(ext_dim=IN_DIM, num_shards=R)
tr = Trainer(loss_fn, lambda k: fn.init(k),
             TrainConfig(lr=3e-3, warmup_steps=4, total_steps=24,
                         weight_decay=0.0, log_every=4,
                         dp_shard=True,          # shard_map over "data"
                         compress_grads=True),   # int8 + error feedback
             mesh=mesh)
state = tr.init_state(jax.random.PRNGKey(0))


def epochs():
    while True:
        yield (graphs, inputs, {"target": list(targets)})


state, logger = tr.fit(state, epochs(), steps=24,
                       compose=pipe.composer(batch_size=32),
                       pipeline=pipe, logger=MetricLogger())
print(f"trained to step {int(np.asarray(state.step))}; "
      f"EF residual live: "
      f"{sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(state.ef)):.2e}")
print(f"per-replica cache stats: {pipe.stats()}")

# --- 5. elastic shrink: lose half the mesh, keep training ----------------
# plan_downsize snaps the surviving count to a power of two (integer
# arithmetic — no float-rounding a replica away); with ckpt_dir set,
# maybe_restore on a new Trainer at the smaller R resumes from the last
# checkpoint (see tests/test_dist_shard.py for the full 8->4 path).
plan = plan_downsize({"data": R}, dead_fraction=0.5)
print(f"elastic plan after losing half the mesh: {plan.new_shape}")
