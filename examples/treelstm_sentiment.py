"""Tree-LSTM sentiment classifier on SST-like data (paper §5 model (d)).

End-to-end: dataset → batch composer → schedule pipeline
(topology-fingerprint cache + shape buckets + async packing) → batched
scheduling of F over G → classification head on root states → AdamW —
the paper's flagship dynamic-NN workload, trained for a few hundred
steps on CPU on the production host path.  Labels ride through the
composer's reordering as aux riders, aligned with their samples.

Note on what composition buys HERE: SST-like random binary parses are
nearly all distinct topologies, so there are no same-fingerprint
groups to batch within an epoch — the composer's wins on this corpus
are depth-sorted bucket occupancy and deterministic epoch replay
(from epoch 2 on, every batch is a schedule-cache hit).  On skewed
corpora (repeated shapes — chains, serving traffic) it additionally
manufactures WITHIN-epoch hits; `bench_graph_construction`'s
`composer/*` rows measure that case.

Run:  PYTHONPATH=src python examples/treelstm_sentiment.py [--steps 150]
      (--no-compose falls back to FIFO epoch slicing)
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute_lazy, readout_roots
from repro.data import ComposedBatchSource, sst_like_dataset
from repro.models.treelstm import TreeLSTMVertex
from repro.optim import adamw_init, adamw_update, warmup_cosine
from repro.pipeline import BucketPolicy, SchedulePipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--no-compose", action="store_true",
                    help="FIFO epoch slicing instead of the composer")
    args = ap.parse_args()

    input_dim = 32
    ds = sst_like_dataset(512, input_dim=input_dim, seed=0)
    fn = TreeLSTMVertex(input_dim=input_dim, hidden=args.hidden, arity=2)

    # The production host path: fingerprint → LRU schedule cache →
    # bucketed pads (few compiled programs) → pack, prefetched on a
    # background thread so the device never waits on packing.
    pipe = SchedulePipeline(input_dim,
                            bucket_policy=BucketPolicy(mode="pow2"))
    rng_np = np.random.default_rng(0)

    key = jax.random.PRNGKey(0)
    params = {
        "cell": fn.init(key),
        "head": jax.random.normal(jax.random.PRNGKey(1),
                                  (args.hidden, 2)) * 0.1,
    }
    opt = adamw_init(params)
    sched_fn = warmup_cosine(3e-3, 20, args.steps)

    def fifo_batches():
        # Epoch-cycled fixed partition: from epoch 2 on, every batch
        # topology has been seen — the schedule cache serves them all.
        order = rng_np.permutation(len(ds))
        parts = [order[i: i + args.batch]
                 for i in range(0, len(ds) - args.batch + 1, args.batch)]
        while True:
            for idx in parts:
                graphs, inputs, labels = ds.batch(idx)
                yield graphs, inputs, {"labels": labels}

    def composed_batches():
        # Pipeline-aware batch formation: any same-fingerprint samples
        # are grouped into whole batches, leftovers fill buckets by
        # depth (occupancy), and the deterministic plan replays every
        # epoch (cache hits from epoch 2 on).  Labels ride through the
        # reordering as aux.
        return ComposedBatchSource(
            ds.graphs, ds.inputs, {"labels": list(ds.labels)},
            composer=pipe.composer(args.batch))

    @jax.jit
    def train_step(params, opt, ext, labels, dev):
        def loss_fn(p):
            buf = execute_lazy(fn, p["cell"], ext, dev)
            root_h = readout_roots(buf, dev)[:, args.hidden:]
            logits = root_h @ p["head"]
            lse = jax.scipy.special.logsumexp(logits, -1)
            nll = lse - jnp.take_along_axis(
                logits, labels[:, None], 1)[:, 0]
            acc = jnp.mean((jnp.argmax(logits, -1) == labels))
            return jnp.mean(nll), acc
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt,
                                      lr=sched_fn(opt.step),
                                      weight_decay=0.0)
        return params, opt, loss, acc

    source = fifo_batches() if args.no_compose else composed_batches()
    batches = pipe.prefetch(source, depth=2)
    try:
        for step in range(1, args.steps + 1):
            b = next(batches)
            labels = jnp.asarray(np.asarray(b.aux["labels"]))
            params, opt, loss, acc = train_step(params, opt, b.ext,
                                                labels, b.dev)
            if step % 25 == 0 or step == 1:
                print(f"step {step:4d}  loss {float(loss):.4f}  "
                      f"acc {float(acc):.2f}")
    finally:
        batches.close()
    s = pipe.stats()
    print(f"done — schedule pipeline: {s['hit_rate']:.0%} cache hit rate, "
          f"{s['compiled_shapes']} compiled shape(s) over {s['batches']} "
          f"batches (async-packed; zero re-tracing on hits; "
          f"{s['packs']} cold packs)")
    if not args.no_compose and getattr(source, "stats", None) is not None:
        cs = source.stats
        print(f"composer: {cs.num_groups} topology groups → "
              f"{cs.group_batches} whole-group + {cs.leftover_batches} "
              f"leftover batches/epoch, predicted epoch-1 hit rate "
              f"{cs.hit_rate:.0%}, mean occupancy {cs.mean_occupancy:.0%}")


if __name__ == "__main__":
    main()
