"""Tree-LSTM sentiment classifier on SST-like data (paper §5 model (d)).

End-to-end: dataset → bucketed packing → batched scheduling of F over
G → classification head on root states → AdamW — the paper's flagship
dynamic-NN workload, trained for a few hundred steps on CPU.

Run:  PYTHONPATH=src python examples/treelstm_sentiment.py [--steps 150]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute_lazy, readout_roots
from repro.core.structure import fit_bucket, pack_external
from repro.data import sst_like_dataset
from repro.models.treelstm import TreeLSTMVertex
from repro.optim import adamw_init, adamw_update, warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    args = ap.parse_args()

    input_dim = 32
    ds = sst_like_dataset(512, input_dim=input_dim, seed=0)
    fn = TreeLSTMVertex(input_dim=input_dim, hidden=args.hidden, arity=2)

    # one bucket → one compiled program for every minibatch
    bucket = fit_bucket(ds.graphs, args.batch)
    rng_np = np.random.default_rng(0)

    key = jax.random.PRNGKey(0)
    params = {
        "cell": fn.init(key),
        "head": jax.random.normal(jax.random.PRNGKey(1),
                                  (args.hidden, 2)) * 0.1,
    }
    opt = adamw_init(params)
    sched_fn = warmup_cosine(3e-3, 20, args.steps)

    def make_batch():
        idx = rng_np.choice(len(ds), args.batch, replace=False)
        graphs, inputs, labels = ds.batch(idx)
        sched = bucket.pack(graphs)
        ext = pack_external(inputs, sched, input_dim)
        return sched.to_device(), jnp.asarray(ext), jnp.asarray(labels)

    @jax.jit
    def train_step(params, opt, ext, labels, dev):
        def loss_fn(p):
            buf = execute_lazy(fn, p["cell"], ext, dev)
            root_h = readout_roots(buf, dev)[:, args.hidden:]
            logits = root_h @ p["head"]
            lse = jax.scipy.special.logsumexp(logits, -1)
            nll = lse - jnp.take_along_axis(
                logits, labels[:, None], 1)[:, 0]
            acc = jnp.mean((jnp.argmax(logits, -1) == labels))
            return jnp.mean(nll), acc
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adamw_update(params, grads, opt,
                                      lr=sched_fn(opt.step),
                                      weight_decay=0.0)
        return params, opt, loss, acc

    for step in range(1, args.steps + 1):
        dev, ext, labels = make_batch()
        params, opt, loss, acc = train_step(params, opt, ext, labels, dev)
        if step % 25 == 0 or step == 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"acc {float(acc):.2f}")
    print("done — one compiled program served every batch "
          "(bucketed packing; zero re-tracing)")


if __name__ == "__main__":
    main()
