"""Continuous-batching serving example (deliverable b, serving side).

The Cavs property at inference time: ONE compiled decode program over a
fixed slot pool; dynamic request arrival/retirement is pure data.  This
mirrors the paper's Var-LSTM batching — variable-length work batched
without recompilation.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.archs import reduced
from repro.models.transformer import TransformerLM
from repro.serve import Request, ServeEngine

cfg = reduced(get_config("granite-3-8b"))
lm = TransformerLM(cfg)
params = lm.init(jax.random.PRNGKey(0))

engine = ServeEngine(lm, params, num_slots=4, max_len=64)
rng = np.random.default_rng(0)

# staggered arrivals: some requests only arrive after serving started
first_wave = [Request(request_id=i,
                      prompt=rng.integers(0, cfg.vocab, size=int(n)),
                      max_new_tokens=8)
              for i, n in enumerate(rng.integers(3, 12, size=6))]
second_wave = [Request(request_id=10 + i,
                       prompt=rng.integers(0, cfg.vocab, size=5),
                       max_new_tokens=6)
               for i in range(3)]

for r in first_wave:
    engine.submit(r)
t0 = time.perf_counter()
for _ in range(4):                      # engine is already decoding...
    engine.step()
for r in second_wave:                   # ...when more requests arrive
    engine.submit(r)
finished = engine.run()
dt = time.perf_counter() - t0

tokens = sum(len(r.output) for r in finished)
print(f"served {len(finished)} requests / {tokens} tokens in "
      f"{engine.ticks} ticks ({dt:.2f}s wall, {tokens/dt:.1f} tok/s)")
print(f"slot pool: {engine.num_slots} slots; requests were admitted and "
      f"retired continuously — no recompilation at any point")
for r in sorted(finished, key=lambda r: r.request_id)[:4]:
    print(f"  req {r.request_id}: prompt[{len(r.prompt)}] → {r.output}")
