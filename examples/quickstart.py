"""Quickstart: the Cavs vertex-centric API in ~30 lines of user code.

Declares an N-ary child-sum Tree-LSTM as a vertex function F (the
paper's Fig. 4), packs a batch of random parse trees G, and runs one
batched training step — no per-sample graph construction anywhere.

Run:  PYTHONPATH=src python examples/quickstart.py

With ``REPRO_TRACE=trace.json`` in the environment the same run also
writes a Chrome/Perfetto timeline (open in ui.perfetto.dev): compose →
pack → cache-hit → H2D → fwd/bwd → reduce spans, correlated by batch
and step ids.  Tracing off costs nothing.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute_lazy, readout_roots
from repro.core.structure import random_binary_tree
from repro.models.treelstm import TreeLSTMVertex
from repro.obs import trace
from repro.pipeline import SchedulePipeline

# --- 1. declare F once (the static vertex function) ----------------------
fn = TreeLSTMVertex(input_dim=32, hidden=64, arity=2)
params = fn.init(jax.random.PRNGKey(0))

# --- 2. per-sample input graphs G arrive as DATA (read "through I/O") ----
rng = np.random.default_rng(0)
graphs = [random_binary_tree(int(rng.integers(4, 20)), rng) for _ in range(8)]
inputs = [rng.standard_normal((g.num_nodes, 32)).astype(np.float32) * 0.1
          for g in graphs]

# --- 3. the schedule pipeline packs the minibatch (host-side, NumPy): ----
# topology-fingerprint cache + shape buckets, so repeated topologies
# skip packing and near-miss batches reuse one compiled program.
pipe = SchedulePipeline(ext_dim=32)
batch = pipe.pack(graphs, inputs)
print(f"packed {len(graphs)} trees: {batch.sched.T} levels × "
      f"{batch.sched.M} slots, occupancy {batch.sched.occupancy:.0%}")

# --- 4. batched training step: schedule F over G, lazy-batched grads -----
@jax.jit
def fwd_bwd(p, e, dev):
    def loss(pp):
        buf = execute_lazy(fn, pp, e, dev)        # Alg. 1 + §3.5 lazy
        root_h = readout_roots(buf, dev)[:, 64:]  # [K, hidden]
        return jnp.mean(root_h ** 2)
    return jax.value_and_grad(loss)(p)


@jax.jit
def apply_grads(p, g):
    return jax.tree.map(lambda w, gw: w - 0.1 * gw, p, g)


def train_step(p, e, dev, step):
    # Under REPRO_TRACE each step is a train.step span with nested
    # fwd/bwd and reduce children; maybe_block brackets the device work
    # so the spans time execution, not dispatch.  With no tracer the
    # span sites are a single is-None check each.
    with trace.correlate(step=step), trace.span("train.step", step=step):
        with trace.span("train.fwd_bwd"):
            l, g = fwd_bwd(p, e, dev)
            trace.maybe_block(g)
        with trace.span("train.reduce"):
            p = trace.maybe_block(apply_grads(p, g))
    return l, p

loss, params = train_step(params, batch.ext, batch.dev, step=0)
print(f"one batched step OK — loss {float(loss):.5f}")
print("the SAME compiled program serves any other batch of trees:")
graphs2 = [random_binary_tree(int(rng.integers(4, 20)), rng)
           for _ in range(8)]
inputs2 = [rng.standard_normal((g.num_nodes, 32)).astype(np.float32) * 0.1
           for g in graphs2]
batch2 = pipe.pack(graphs2, inputs2)       # same bucket → no re-compile
loss2, params = train_step(params, batch2.ext, batch2.dev, step=1)
print(f"second batch, zero graph-construction overhead — "
      f"loss {float(loss2):.5f}")
print(f"pipeline stats: {pipe.stats()}")

# --- 5. pipeline-aware batch formation: COMPOSE batches for cache hits ---
# A corpus with repeated topologies (the real-world case).  FIFO slicing
# interleaves them — distinct batch fingerprints, no hits; the composer
# groups same-fingerprint samples into whole batches, so every batch
# after a group's first is a schedule-cache hit.
corpus = [graphs[i % 4] for i in range(64)]          # heavy repetition
corpus_in = [inputs[i % 4] for i in range(64)]
composed, stats = pipe.compose(corpus, corpus_in, batch_size=8)
for cb in composed:
    pipe.pack(*cb.as_item())             # sample_ids ride in aux
print(f"composed {stats.num_batches} batches from {stats.num_groups} "
      f"topology groups: predicted hit rate {stats.hit_rate:.0%}, "
      f"measured {pipe.cache.hit_rate:.0%} overall, occupancy "
      f"{stats.mean_occupancy:.0%}")
print("(set REPRO_SCHED_PERSIST=<dir> and re-run: the warm restart "
      "packs zero schedules — they load from the on-disk store)")
