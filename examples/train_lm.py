"""End-to-end LM training driver (deliverable b): train a ~100M-param
transformer for a few hundred steps on CPU with the full production
stack — data pipeline, AdamW + schedule, grad accumulation, async
checkpointing, metric logging, auto-resume.

Run:  PYTHONPATH=src python examples/train_lm.py \
          [--steps 300] [--ckpt /tmp/lm_ckpt]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.archs import reduced
from repro.data import lm_batches, synthetic_corpus
from repro.models.transformer import TransformerLM
from repro.pipeline import AsyncPacker
from repro.train import MetricLogger, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--n-micro", type=int, default=2)
    args = ap.parse_args()

    # ~100M params: widen the reduced granite config
    cfg = dataclasses.replace(
        reduced(get_config("granite-3-8b")),
        name="granite-100m", num_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=1536, vocab=8192, head_dim=64)
    lm = TransformerLM(cfg)
    n_params = cfg.param_count()
    print(f"arch={cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={args.batch}×{args.seq} tokens, "
          f"n_micro={args.n_micro}")

    trainer = Trainer(
        lambda p, b: lm.loss(p, b), lm.init,
        TrainConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps,
                    n_micro=args.n_micro, ckpt_dir=args.ckpt,
                    ckpt_every=100, log_every=20))
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, start = trainer.maybe_restore(state)
    if start:
        print(f"resumed from checkpoint at step {start}")

    corpus = synthetic_corpus(3_000_000, cfg.vocab, seed=0)
    # The schedule pipeline's async packing stage doubles as a device
    # stager for plain token batches: host batch assembly + transfer
    # overlap with the previous step's compute (Trainer.fit closes the
    # background producer when the loop exits).
    batches = AsyncPacker(
        lm_batches(corpus, args.batch, args.seq, seed=0),
        lambda b: {k: jax.device_put(np.asarray(v)) for k, v in b.items()},
        depth=2)
    logger = MetricLogger(tokens_per_step=args.batch * args.seq)
    state, logger = trainer.fit(state, batches, steps=args.steps,
                                logger=logger)
    first = next(r for r in logger.history if "loss" in r)
    last = logger.history[-1]
    print(f"loss {first['loss']:.3f} → {last['loss']:.3f} over "
          f"{int(np.asarray(state.step))} steps "
          f"({last.get('tokens_per_sec', 0):.0f} tok/s)")
    assert last["loss"] < first["loss"], "training must make progress"


if __name__ == "__main__":
    main()
