"""Encoder-decoder via push/pull composition (paper §3.1: "declare
multiple vertex functions ... and connect them appropriately").

Two (F, G) structures: an encoder LSTM over the source chain and a
decoder LSTM over the target chain.  The decoder PULLS the encoder's
final state (the cross-structure external data path) — in this
framework the pull is realized by feeding the encoder's root state into
the decoder's external-input rows.

Run:  PYTHONPATH=src python examples/encoder_decoder.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import execute, readout_nodes, readout_roots
from repro.core.structure import chain, pack_batch, pack_external
from repro.models.rnn import LSTMVertex

B, SRC_LEN, TGT_LEN, D, H = 4, 10, 7, 16, 24
rng = np.random.default_rng(0)

enc = LSTMVertex(input_dim=D, hidden=H)
# decoder pulls [token_embedding | encoder_context] at every step
dec = LSTMVertex(input_dim=D + 2 * H, hidden=H)
params = {"enc": enc.init(jax.random.PRNGKey(0)),
          "dec": dec.init(jax.random.PRNGKey(1))}

# --- encoder structure: source chains ------------------------------------
src_graphs = [chain(SRC_LEN) for _ in range(B)]
src_inputs = [rng.standard_normal((SRC_LEN, D)).astype(np.float32) * 0.1
              for _ in range(B)]
enc_sched = pack_batch(src_graphs)
enc_ext = jnp.asarray(pack_external(src_inputs, enc_sched, D))
enc_dev = enc_sched.to_device()

# --- decoder structure: target chains -------------------------------------
tgt_graphs = [chain(TGT_LEN) for _ in range(B)]
tgt_tokens = [rng.standard_normal((TGT_LEN, D)).astype(np.float32) * 0.1
              for _ in range(B)]
dec_sched = pack_batch(tgt_graphs)
dec_dev = dec_sched.to_device()


# 1. schedule F_enc over the source chains; the root state is the
#    encoder's PUSH — the value made visible outside (F_enc, G_src).
# 2. pack decoder pulls: concat token embeds with the pushed context.
enc_buf = execute(enc, params["enc"], enc_dev, enc_ext).buf
context = np.asarray(readout_roots(enc_buf, enc_dev))   # [B, 2H]
dec_inputs = [np.concatenate(
    [tgt_tokens[k], np.repeat(context[k][None], TGT_LEN, 0)], axis=1)
    for k in range(B)]
dec_ext = jnp.asarray(pack_external(dec_inputs, dec_sched, D + 2 * H))


@jax.jit
def decode(params, dec_ext):
    buf = execute(dec, params["dec"], dec_dev, dec_ext).buf
    return readout_nodes(buf, dec_dev)[:, :, H:]        # [B, T, H]

outs = decode(params, dec_ext)
print(f"encoder chains {SRC_LEN} steps → context [B, {2*H}]")
print(f"decoder chains {TGT_LEN} steps pulling context → outputs "
      f"{outs.shape}")
assert np.all(np.isfinite(np.asarray(outs)))
print("enc-dec composition OK (two F's, push/pull connected)")
