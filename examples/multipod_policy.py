"""Distribution-policy walkthrough (runs on CPU with 8 fake devices via
a subprocess-style guard): shows the logical-axis sharding rules, the
GPipe pipeline over a pod axis, int8+EF gradient compression, and an
elastic down-scale replan — the 1000-node toolkit in miniature.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/multipod_policy.py
"""

import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import compress, elastic, pipeline
from repro.dist.sharding import ShardingPolicy, param_specs, policy_for_mesh

# --- 1. mesh + policy ------------------------------------------------------
devs = np.asarray(jax.devices()).reshape(2, 2, 2)
mesh = Mesh(devs, ("pod", "data", "model"))
policy = policy_for_mesh(mesh, fsdp=True)
print("mesh:", dict(mesh.shape))
print("activation rules:", policy.rules())

# --- 2. parameter sharding by role ----------------------------------------
params = {"embed": jnp.zeros((64, 16)),
          "attn": {"wq": jnp.zeros((16, 4, 8)), "wk": jnp.zeros((16, 2, 8)),
                   "wo": jnp.zeros((4, 8, 16))},
          "moe": {"w_gate": jnp.zeros((4, 16, 32))}}
specs = param_specs(params, mesh, policy)
for k, v in jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)):
    print(" ", jax.tree_util.keystr(k), "→", v)

# --- 3. pipeline over the pod axis ----------------------------------------
pmesh = Mesh(devs.reshape(8)[:2], ("pod",))
stage = lambda p, x: jnp.tanh(x @ p)
stacked = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8)) * 0.5
xs = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))
floss = pipeline.gpipe_spmd(stage, pmesh, loss_fn=lambda a: jnp.sum(a ** 2))
with pmesh:
    loss = float(floss(stacked, xs))
print(f"gpipe over pod axis: loss={loss:.3f}, "
      f"bubble={pipeline.bubble_fraction(2, 4):.0%}")

# --- 4. int8 + error-feedback cross-pod reduction ---------------------------
from jax.experimental.shard_map import shard_map
x = jnp.stack([jnp.full((8,), 1.0), jnp.full((8,), 3.0)])
f = shard_map(lambda v: compress.cross_pod_mean_int8(v[0])[None],
              mesh=pmesh, in_specs=P("pod"), out_specs=P("pod"))
with pmesh:
    out = f(x)
print("cross-pod int8 mean of (1, 3):", float(out[0, 0]),
      "(4x fewer bytes over the slow link)")

# --- 5. elastic down-scale plan ---------------------------------------------
plan = elastic.plan_downsize({"data": 16, "model": 16}, dead_fraction=0.2)
print(f"elastic: lose 20% of chips → mesh {plan.old_shape} → "
      f"{plan.new_shape} (TP preserved, {plan.dropped_rows} DP rows "
      f"dropped; checkpoint restores reshard automatically)")
